//! Monte Carlo boxes (paper Fig. 1a): unbiased single-sample estimators of
//! the normalized distances θ_i = ρ(x_q, x_i)/d, wrapped as bandit arms.
//!
//! Three boxes, matching the paper:
//!  * [`DenseArms`] — §III, Eq. (4): sample a uniform coordinate J and
//!    observe ρ_J(x_q, x_i). Works for any separable ρ; we ship ℓ2² and ℓ1.
//!  * [`SparseArms`] — §IV-A, Eq. (12): sample only over the supports,
//!    reweighted to stay unbiased; O(1) per sample via the CSR dictionary.
//!  * rotated box — §IV-B: [`DenseArms`] over a dataset preprocessed by
//!    `data::rotate::Rotation` (the box itself is unchanged; the rotation
//!    shrinks its sub-Gaussian constant, Lemma 3).
//!
//! The batched pull path is delegated to a [`PullEngine`] so the same
//! bandit logic runs over the scalar reference loops, the optimized native
//! kernel, or the AOT-compiled JAX/Pallas artifact (runtime::pjrt).

use crate::data::dense::{DenseDataset, Metric};
use crate::data::sparse::SparseDataset;
use crate::metrics::Counter;
use crate::util::rng::Rng;

/// One query's pull work within a multi-query coalesced round: the staged
/// coordinate draws of a single bandit instance, to be resolved against
/// the shared dataset together with every other in-flight query's pulls
/// (see [`PullEngine::pull_batch`]).
#[derive(Clone, Copy, Debug)]
pub struct PullRequest<'a> {
    /// the query vector this request's bandit is serving
    pub query: &'a [f32],
    /// dataset rows (arm ids) to pull
    pub rows: &'a [u32],
    /// shared coordinate draws for every row of this request
    pub coord_ids: &'a [u32],
}

/// Which global dataset rows an engine can currently answer — reported
/// by substrates with failure modes (the replicated remote ring) while
/// part of the dataset is unreachable, and threaded through
/// [`crate::coordinator::knn::KnnResult`] as the degraded-mode coverage
/// annotation.
#[derive(Clone, Debug, PartialEq)]
pub struct Coverage {
    /// live global row ranges `[start, end)`, sorted and disjoint
    pub live: Vec<(u32, u32)>,
    /// total rows of the dataset (`n`)
    pub rows_total: usize,
}

impl Coverage {
    /// Number of rows inside the live ranges.
    pub fn rows_live(&self) -> usize {
        self.live.iter().map(|&(a, b)| (b - a) as usize).sum()
    }

    /// Fraction of the dataset that is answerable (`rows_live / n`).
    pub fn fraction(&self) -> f64 {
        self.rows_live() as f64 / self.rows_total.max(1) as f64
    }
}

/// Opaque handle to a wave submitted through the pipelined half of the
/// [`PullEngine`] API ([`PullEngine::submit_pull_batch`] and friends).
///
/// Blocking engines resolve the wave eagerly at submit time and park the
/// results inside the ticket ([`WaveTicket::ready_sums`] /
/// [`WaveTicket::ready_dists`]); pipelined engines (the multiplexed
/// remote ring client) return a [`WaveTicket::deferred`] key into their
/// in-flight table and resolve it in `complete_*`. Either way the
/// completed outputs are bitwise identical to the blocking call —
/// submit/complete only moves *when* the caller blocks, never what is
/// computed.
#[derive(Debug)]
pub struct WaveTicket {
    /// eagerly computed results — `(vals, [])` for a dists wave
    ready: Option<(Vec<f64>, Vec<f64>)>,
    key: u64,
}

impl WaveTicket {
    /// A ticket already carrying a sums wave's `(Σx, Σx²)` results.
    pub fn ready_sums(sum: Vec<f64>, sq: Vec<f64>) -> WaveTicket {
        WaveTicket { ready: Some((sum, sq)), key: 0 }
    }

    /// A ticket already carrying an exact-distance wave's results.
    pub fn ready_dists(vals: Vec<f64>) -> WaveTicket {
        WaveTicket { ready: Some((vals, Vec::new())), key: 0 }
    }

    /// A ticket whose results are still in flight; `key` indexes the
    /// engine's own in-flight table.
    pub fn deferred(key: u64) -> WaveTicket {
        WaveTicket { ready: None, key }
    }

    /// The engine-private in-flight key of a deferred ticket.
    pub fn key(&self) -> u64 {
        self.key
    }

    /// Move out the eager results, if the wave was resolved at submit.
    pub fn take_ready(&mut self) -> Option<(Vec<f64>, Vec<f64>)> {
        self.ready.take()
    }
}

/// Batched compute engine for dense pulls. Implementations:
/// [`ScalarEngine`] (reference), `runtime::native::NativeEngine`
/// (optimized hot path), `runtime::pjrt::PjrtEngine` (AOT artifact).
///
/// Every wave exists in two forms: the blocking calls
/// (`partial_sums`/`exact_dists`/`pull_batch`) and the pipelined
/// submit/complete split (`submit_* -> WaveTicket`, `complete_*`). The
/// default submit resolves eagerly via the blocking call, so the split
/// API is available on every engine with unchanged semantics; engines
/// with real I/O in the middle (the remote ring) override it so the wave
/// is on the wire when submit returns and the caller overlaps work with
/// the round trip. `pipelined()` tells drivers whether the split
/// actually buys overlap.
pub trait PullEngine {
    /// For each row id, the sum and sum-of-squares over `coord_ids` of
    /// `metric.coord(data[row][j], query[j])` (raw partial moments, not
    /// normalized). Coordinates are shared across the batch. The second
    /// moment feeds the empirical-variance confidence intervals
    /// (Appendix D-A).
    fn partial_sums(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        coord_ids: &[u32],
        metric: Metric,
        out_sum: &mut Vec<f64>,
        out_sq: &mut Vec<f64>,
    );

    /// Exact (un-normalized) distances of the given rows to the query.
    fn exact_dists(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        metric: Metric,
        out: &mut Vec<f64>,
    );

    /// Resolve many concurrent queries' pull requests against one shared
    /// dataset in a single pass. Results are concatenated in request
    /// order: the outputs for `reqs[i]` are exactly what
    /// [`PullEngine::partial_sums`] would produce for
    /// `(reqs[i].query, reqs[i].rows, reqs[i].coord_ids)` — engines may
    /// reorder the *work* (e.g. sweep the dataset block-by-block so a row
    /// shared by many queries is loaded once) but not the results.
    ///
    /// The default implementation is the semantic reference: one
    /// `partial_sums` call per request.
    fn pull_batch(
        &mut self,
        data: &DenseDataset,
        reqs: &[PullRequest<'_>],
        metric: Metric,
        out_sum: &mut Vec<f64>,
        out_sq: &mut Vec<f64>,
    ) {
        out_sum.clear();
        out_sq.clear();
        let mut s = Vec::new();
        let mut q = Vec::new();
        for r in reqs {
            self.partial_sums(data, r.query, r.rows, r.coord_ids, metric,
                              &mut s, &mut q);
            out_sum.extend_from_slice(&s);
            out_sq.extend_from_slice(&q);
        }
    }

    /// Pipelined form of [`PullEngine::partial_sums`]: stage the wave
    /// and return a ticket; the results materialize at
    /// [`PullEngine::complete_sums`]. The default resolves eagerly (no
    /// overlap, identical results).
    fn submit_partial_sums(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        coord_ids: &[u32],
        metric: Metric,
    ) -> WaveTicket {
        let (mut s, mut q) = (Vec::new(), Vec::new());
        self.partial_sums(data, query, rows, coord_ids, metric, &mut s,
                          &mut q);
        WaveTicket::ready_sums(s, q)
    }

    /// Pipelined form of [`PullEngine::exact_dists`]; completed with
    /// [`PullEngine::complete_dists`].
    fn submit_exact_dists(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        metric: Metric,
    ) -> WaveTicket {
        let mut vals = Vec::new();
        self.exact_dists(data, query, rows, metric, &mut vals);
        WaveTicket::ready_dists(vals)
    }

    /// Pipelined form of [`PullEngine::pull_batch`]: stage the whole
    /// multi-query wave and return a ticket; completed with
    /// [`PullEngine::complete_sums`]. A pipelined engine has the wave on
    /// the wire when this returns, so the caller can overlap per-query
    /// bookkeeping with the round trip; several tickets may be in
    /// flight at once and completed in any order.
    fn submit_pull_batch(
        &mut self,
        data: &DenseDataset,
        reqs: &[PullRequest<'_>],
        metric: Metric,
    ) -> WaveTicket {
        let (mut s, mut q) = (Vec::new(), Vec::new());
        self.pull_batch(data, reqs, metric, &mut s, &mut q);
        WaveTicket::ready_sums(s, q)
    }

    /// Resolve a sums-wave ticket (`submit_partial_sums` /
    /// `submit_pull_batch`) into the caller's output buffers — blocking
    /// until the wave's replies arrived, bitwise identical to the
    /// blocking call that would have produced them.
    fn complete_sums(&mut self, mut ticket: WaveTicket,
                     out_sum: &mut Vec<f64>, out_sq: &mut Vec<f64>) {
        match ticket.take_ready() {
            Some((s, q)) => {
                *out_sum = s;
                *out_sq = q;
            }
            None => panic!(
                "engine '{}' returned a deferred WaveTicket but does not \
                 override complete_sums",
                self.name()
            ),
        }
    }

    /// Resolve an exact-distance ticket (`submit_exact_dists`).
    fn complete_dists(&mut self, mut ticket: WaveTicket,
                      out: &mut Vec<f64>) {
        match ticket.take_ready() {
            Some((vals, _)) => *out = vals,
            None => panic!(
                "engine '{}' returned a deferred WaveTicket but does not \
                 override complete_dists",
                self.name()
            ),
        }
    }

    /// True when `submit_*` genuinely overlaps I/O with the caller
    /// (the wave is in flight when submit returns). Drivers use this to
    /// pick the split API only where it buys overlap — the blocking
    /// calls reuse caller scratch buffers, which the eager default
    /// ticket cannot.
    fn pipelined(&self) -> bool {
        false
    }

    /// The rows this engine can answer right now. `None` (the default,
    /// and the only value local engines ever report) means the full
    /// dataset. A remote engine running in degraded mode returns
    /// `Some(coverage)` while shards with no live replica exist — the
    /// k-NN drivers then answer exact top-k over the surviving rows
    /// with the coverage annotation instead of erroring. Callers must
    /// not send waves touching rows outside a reported coverage.
    fn coverage(&mut self) -> Option<Coverage> {
        None
    }

    /// Worst-case bias, in θ-units, that this engine's **sampled**
    /// estimates (`partial_sums`/`pull_batch`) may carry against
    /// `query` beyond sampling noise. `0.0` (the default — engines
    /// computing on the exact f32 rows) means estimates are unbiased;
    /// the quantized native tier reports its reconstruction-error
    /// bound (`runtime::quant`). Drivers fold the value into
    /// `BanditParams::bias` before a run, widening every non-exact
    /// confidence half-width so UCB/LCB stay valid bounds on the true
    /// θ and the PAC accounting absorbs the approximation. Exact
    /// distances (`exact_dists`) are never biased.
    fn quant_bias(&mut self, data: &DenseDataset, query: &[f32],
                  metric: Metric) -> f64 {
        let _ = (data, query, metric);
        0.0
    }

    /// Bound every subsequent wave by an absolute deadline: past it, an
    /// engine with real I/O in the middle must stop waiting and fail the
    /// wave with a `deadline exceeded` error
    /// (`runtime::wire::is_deadline_error`) instead of running out its
    /// full I/O timeout. `None` (the default state) removes the bound.
    /// Local engines compute synchronously and ignore it — the batch
    /// drivers enforce the budget *between* rounds for every engine, so
    /// this hook only tightens the intra-wave waits. Drivers call it at
    /// entry with their budget and implementations must treat each call
    /// as replacing the previous bound.
    fn set_deadline(&mut self, deadline: Option<std::time::Instant>) {
        let _ = deadline;
    }

    /// Abandon an in-flight wave without waiting for its results — the
    /// completion-side filter of speculative execution. The speculative
    /// batch driver submits a predicted round-t+1 wave before round t
    /// retires; when the prediction misses, it abandons the ticket
    /// instead of completing it, so a discarded wave consumes **no**
    /// failover attempts and **no** deadline budget (those are only
    /// spent while *waiting* on a wave). Eager engines resolved the wave
    /// at submit, so the default just drops the ticket's parked results;
    /// a pipelined engine must drop its in-flight bookkeeping for
    /// `ticket.key()` and let the late reply be discarded by its demux
    /// layer. Abandoning is always safe: the wave's computation is pure
    /// and its results are simply never observed.
    fn abandon_wave(&mut self, ticket: WaveTicket) {
        drop(ticket);
    }

    fn name(&self) -> &'static str;
}

/// Forwarding impl so a boxed engine (e.g. from
/// `runtime::build_host_engine`, which picks scalar/native/sharded from
/// config) can drive the generic knn/batch drivers directly. Each call
/// dynamically dispatches to the inner engine's own implementation —
/// including its `pull_batch` override.
impl PullEngine for Box<dyn PullEngine + Send> {
    fn partial_sums(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        coord_ids: &[u32],
        metric: Metric,
        out_sum: &mut Vec<f64>,
        out_sq: &mut Vec<f64>,
    ) {
        (**self).partial_sums(data, query, rows, coord_ids, metric,
                              out_sum, out_sq)
    }

    fn exact_dists(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        metric: Metric,
        out: &mut Vec<f64>,
    ) {
        (**self).exact_dists(data, query, rows, metric, out)
    }

    fn pull_batch(
        &mut self,
        data: &DenseDataset,
        reqs: &[PullRequest<'_>],
        metric: Metric,
        out_sum: &mut Vec<f64>,
        out_sq: &mut Vec<f64>,
    ) {
        (**self).pull_batch(data, reqs, metric, out_sum, out_sq)
    }

    fn submit_partial_sums(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        coord_ids: &[u32],
        metric: Metric,
    ) -> WaveTicket {
        (**self).submit_partial_sums(data, query, rows, coord_ids, metric)
    }

    fn submit_exact_dists(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        metric: Metric,
    ) -> WaveTicket {
        (**self).submit_exact_dists(data, query, rows, metric)
    }

    fn submit_pull_batch(
        &mut self,
        data: &DenseDataset,
        reqs: &[PullRequest<'_>],
        metric: Metric,
    ) -> WaveTicket {
        (**self).submit_pull_batch(data, reqs, metric)
    }

    fn complete_sums(&mut self, ticket: WaveTicket, out_sum: &mut Vec<f64>,
                     out_sq: &mut Vec<f64>) {
        (**self).complete_sums(ticket, out_sum, out_sq)
    }

    fn complete_dists(&mut self, ticket: WaveTicket, out: &mut Vec<f64>) {
        (**self).complete_dists(ticket, out)
    }

    fn pipelined(&self) -> bool {
        (**self).pipelined()
    }

    fn coverage(&mut self) -> Option<Coverage> {
        (**self).coverage()
    }

    fn quant_bias(&mut self, data: &DenseDataset, query: &[f32],
                  metric: Metric) -> f64 {
        (**self).quant_bias(data, query, metric)
    }

    fn set_deadline(&mut self, deadline: Option<std::time::Instant>) {
        (**self).set_deadline(deadline)
    }

    fn abandon_wave(&mut self, ticket: WaveTicket) {
        (**self).abandon_wave(ticket)
    }

    fn name(&self) -> &'static str {
        (**self).name()
    }
}

/// Straightforward scalar loops — the semantic reference for every other
/// engine (runtime parity tests compare against this).
#[derive(Default, Clone, Debug)]
pub struct ScalarEngine;

impl PullEngine for ScalarEngine {
    fn partial_sums(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        coord_ids: &[u32],
        metric: Metric,
        out_sum: &mut Vec<f64>,
        out_sq: &mut Vec<f64>,
    ) {
        out_sum.clear();
        out_sq.clear();
        for &r in rows {
            let row = data.row(r as usize);
            let mut acc = 0f64;
            let mut acc2 = 0f64;
            for &j in coord_ids {
                let v =
                    metric.coord(row[j as usize], query[j as usize]) as f64;
                acc += v;
                acc2 += v * v;
            }
            out_sum.push(acc);
            out_sq.push(acc2);
        }
    }

    fn exact_dists(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        metric: Metric,
        out: &mut Vec<f64>,
    ) {
        out.clear();
        for &r in rows {
            out.push(crate::data::dense::dist_slices(
                data.row(r as usize),
                query,
                metric,
            ));
        }
    }

    fn name(&self) -> &'static str {
        "scalar"
    }
}

/// A set of bandit arms with the operations BMO UCB needs.
///
/// Means are *normalized* distances θ ∈ [0, ~): θ_i = ρ(x_q, x_i)/d.
/// Every sample charges the counter 1 unit; exact evaluation charges
/// `exact_cost(arm)` units (the [`crate::metrics`] accounting contract).
pub trait ArmSet {
    fn n_arms(&self) -> usize;

    /// MAX_PULLS for this arm: past this, exact evaluation is cheaper.
    /// Dense: d. Sparse: |S_q| + |S_i|.
    fn max_pulls(&self, arm: usize) -> u64;

    /// Units charged by `exact_mean`.
    fn exact_cost(&self, arm: usize) -> u64;

    /// Draw `t` samples of arm `arm`; return (Σx, Σx²).
    fn pull(&mut self, arm: usize, t: u64, rng: &mut Rng, c: &mut Counter)
            -> (f64, f64);

    /// Pull every arm in `arms` `t` times (shared coordinate draw allowed;
    /// per-arm unbiasedness is preserved). Returns per-arm (Σx, Σx²).
    fn pull_batch(&mut self, arms: &[usize], t: u64, rng: &mut Rng,
                  c: &mut Counter, out_sum: &mut Vec<f64>,
                  out_sq: &mut Vec<f64>) {
        out_sum.clear();
        out_sq.clear();
        for &a in arms {
            let (s, s2) = self.pull(a, t, rng, c);
            out_sum.push(s);
            out_sq.push(s2);
        }
    }

    /// Exact θ (normalized).
    fn exact_mean(&mut self, arm: usize, c: &mut Counter) -> f64;

    /// Map an arm index back to the caller's id space (dataset row).
    fn arm_id(&self, arm: usize) -> u32;
}

/// Dense Monte Carlo box over a [`DenseDataset`] (Eq. 4).
///
/// Borrows its query vector and candidate-row list so the multi-query
/// batch driver can rebuild a view per scheduling round without cloning
/// O(n) state (the bandit owns all persistent per-query state).
pub struct DenseArms<'a, E: PullEngine> {
    data: &'a DenseDataset,
    query: &'a [f32],
    /// candidate rows (query excluded by the caller)
    rows: &'a [u32],
    metric: Metric,
    engine: &'a mut E,
    scratch_coords: Vec<u32>,
    scratch_sums: Vec<f64>,
    scratch_sqs: Vec<f64>,
}

impl<'a, E: PullEngine> DenseArms<'a, E> {
    pub fn new(data: &'a DenseDataset, query: &'a [f32], rows: &'a [u32],
               metric: Metric, engine: &'a mut E) -> Self {
        assert_eq!(query.len(), data.d);
        assert!(!rows.is_empty(), "need at least one candidate arm");
        DenseArms {
            data,
            query,
            rows,
            metric,
            engine,
            scratch_coords: Vec::new(),
            scratch_sums: Vec::new(),
            scratch_sqs: Vec::new(),
        }
    }

    /// All rows except `exclude` (self-query in graph construction).
    pub fn candidates(n: usize, exclude: Option<usize>) -> Vec<u32> {
        (0..n as u32)
            .filter(|&i| Some(i as usize) != exclude)
            .collect()
    }

    fn sample_coords(&mut self, t: u64, rng: &mut Rng) {
        self.scratch_coords.clear();
        let d = self.data.d;
        for _ in 0..t {
            self.scratch_coords.push(rng.below(d) as u32);
        }
    }

    /// Stage a uniform `t`-pull of `arms` for a coalesced multi-query
    /// round: sample the shared coordinates, charge the counter, and
    /// resolve arm indices to dataset rows — but do *not* touch the
    /// engine. RNG and counter effects are identical to
    /// [`ArmSet::pull_batch`]; the caller must execute the staged request
    /// via [`PullEngine::pull_batch`] and feed the per-arm (Σx, Σx²) back
    /// to the bandit, which makes the batch driver bitwise-identical to
    /// the per-query path under a fixed per-query RNG.
    pub fn stage_pull(&mut self, arms: &[usize], t: u64, rng: &mut Rng,
                      c: &mut Counter) -> (Vec<u32>, Vec<u32>) {
        self.sample_coords(t, rng);
        c.add(t * arms.len() as u64);
        let rows: Vec<u32> = arms.iter().map(|&a| self.rows[a]).collect();
        (rows, self.scratch_coords.clone())
    }
}

impl<'a, E: PullEngine> ArmSet for DenseArms<'a, E> {
    fn n_arms(&self) -> usize {
        self.rows.len()
    }

    fn max_pulls(&self, _arm: usize) -> u64 {
        self.data.d as u64
    }

    fn exact_cost(&self, _arm: usize) -> u64 {
        self.data.d as u64
    }

    fn pull(&mut self, arm: usize, t: u64, rng: &mut Rng, c: &mut Counter)
            -> (f64, f64) {
        // samples are coordinate distances; E[X] = ρ/d = θ, so the sum of
        // t samples estimates t·θ directly — no extra scaling. Routed
        // through the engine: the ragged near-MAX_PULLS pulls otherwise
        // dominate hard queries on the scalar path (§Perf iteration 3).
        self.sample_coords(t, rng);
        c.add(t);
        let row = [self.rows[arm]];
        self.engine.partial_sums(
            self.data,
            self.query,
            &row,
            &self.scratch_coords,
            self.metric,
            &mut self.scratch_sums,
            &mut self.scratch_sqs,
        );
        (self.scratch_sums[0], self.scratch_sqs[0])
    }

    fn pull_batch(&mut self, arms: &[usize], t: u64, rng: &mut Rng,
                  c: &mut Counter, out_sum: &mut Vec<f64>,
                  out_sq: &mut Vec<f64>) {
        self.sample_coords(t, rng);
        c.add(t * arms.len() as u64);
        // gather row ids
        let mut row_ids = Vec::with_capacity(arms.len());
        for &a in arms {
            row_ids.push(self.rows[a]);
        }
        self.engine.partial_sums(
            self.data,
            self.query,
            &row_ids,
            &self.scratch_coords,
            self.metric,
            &mut self.scratch_sums,
            &mut self.scratch_sqs,
        );
        out_sum.clear();
        out_sum.extend_from_slice(&self.scratch_sums);
        out_sq.clear();
        out_sq.extend_from_slice(&self.scratch_sqs);
    }

    fn exact_mean(&mut self, arm: usize, c: &mut Counter) -> f64 {
        c.add(self.exact_cost(arm));
        let row = [self.rows[arm]];
        // engine path: the unrolled native kernel is ~5x faster than the
        // scalar reference here, and exact evals dominate hard queries
        self.engine.exact_dists(self.data, self.query, &row, self.metric,
                                &mut self.scratch_sums);
        self.scratch_sums[0] / self.data.d as f64
    }

    fn arm_id(&self, arm: usize) -> u32 {
        self.rows[arm]
    }
}

/// Sparse Monte Carlo box (Eq. 12): support-restricted importance sampler.
///
/// One sample of arm i (query row q):
///   with prob n_q/(n_q+n_i): draw t uniform from S_q,
///     X = (n_q+n_i)/(2d) · ρ_t(x_q, x_i) · (1 + 1{t ∉ S_i})
///   else symmetric from S_i.
/// Unbiased for θ_i = ρ(x_q, x_i)/d (Appendix C-A), O(1) per sample.
pub struct SparseArms<'a> {
    data: &'a SparseDataset,
    query_row: usize,
    rows: &'a [u32],
    metric: Metric,
}

impl<'a> SparseArms<'a> {
    pub fn new(data: &'a SparseDataset, query_row: usize, rows: &'a [u32],
               metric: Metric) -> Self {
        assert!(query_row < data.n);
        assert!(!rows.is_empty());
        SparseArms { data, query_row, rows, metric }
    }

    #[inline]
    fn one_sample(&self, point: usize, rng: &mut Rng) -> f64 {
        let q = self.query_row;
        let nq = self.data.nnz(q);
        let ni = self.data.nnz(point);
        let tot = nq + ni;
        if tot == 0 {
            return 0.0; // identical empty supports: distance 0
        }
        let d = self.data.d as f64;
        let from_q = rng.below(tot) < nq;
        let (src, other) = if from_q { (q, point) } else { (point, q) };
        let t = rng.below(self.data.nnz(src));
        let (j, v_src) = self.data.support_entry(src, t);
        let v_other = self.data.get(other, j);
        let in_other = self.data.contains(other, j);
        let coord = if from_q {
            self.metric.coord(v_src, v_other)
        } else {
            self.metric.coord(v_other, v_src)
        } as f64;
        let mult = if in_other { 1.0 } else { 2.0 };
        (tot as f64) / (2.0 * d) * coord * mult
    }
}

impl<'a> ArmSet for SparseArms<'a> {
    fn n_arms(&self) -> usize {
        self.rows.len()
    }

    fn max_pulls(&self, arm: usize) -> u64 {
        let p = self.rows[arm] as usize;
        (self.data.nnz(self.query_row) + self.data.nnz(p)).max(1) as u64
    }

    fn exact_cost(&self, arm: usize) -> u64 {
        self.max_pulls(arm)
    }

    fn pull(&mut self, arm: usize, t: u64, rng: &mut Rng, c: &mut Counter)
            -> (f64, f64) {
        c.add(t);
        let point = self.rows[arm] as usize;
        let mut acc = 0f64;
        let mut acc2 = 0f64;
        for _ in 0..t {
            let v = self.one_sample(point, rng);
            acc += v;
            acc2 += v * v;
        }
        (acc, acc2)
    }

    fn exact_mean(&mut self, arm: usize, c: &mut Counter) -> f64 {
        let point = self.rows[arm] as usize;
        self.data.dist(self.query_row, point, self.metric, c)
            / self.data.d as f64
    }

    fn arm_id(&self, arm: usize) -> u32 {
        self.rows[arm]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::util::proptest;

    #[test]
    fn dense_pull_is_unbiased() {
        let ds = synthetic::gaussian_iid(4, 128, 1);
        let mut engine = ScalarEngine;
        let query = ds.row_vec(0);
        let rows = DenseArms::<ScalarEngine>::candidates(4, Some(0));
        let mut arms =
            DenseArms::new(&ds, &query, &rows, Metric::L2Sq, &mut engine);
        let mut rng = Rng::new(2);
        let mut c = Counter::new();
        let theta_exact = arms.exact_mean(0, &mut c);
        let t = 40_000u64;
        let (sum, _sq) = arms.pull(0, t, &mut rng, &mut c);
        let est = sum / t as f64;
        assert!(
            (est - theta_exact).abs() < 0.05 * theta_exact.max(0.1),
            "est {est} vs exact {theta_exact}"
        );
    }

    #[test]
    fn dense_pull_batch_matches_scalar_engine_semantics() {
        let ds = synthetic::gaussian_iid(8, 64, 3);
        let mut engine = ScalarEngine;
        let query = ds.row_vec(0);
        let rows = DenseArms::<ScalarEngine>::candidates(8, Some(0));
        let mut arms =
            DenseArms::new(&ds, &query, &rows, Metric::L1, &mut engine);
        let mut rng = Rng::new(4);
        let mut c = Counter::new();
        let (mut out, mut out_sq) = (Vec::new(), Vec::new());
        arms.pull_batch(&[0, 3, 5], 16, &mut rng, &mut c, &mut out,
                        &mut out_sq);
        assert_eq!(out.len(), 3);
        assert_eq!(out_sq.len(), 3);
        assert_eq!(c.get(), 48); // 3 arms × 16 pulls
        assert!(out.iter().all(|&s| s >= 0.0));
        // Σx² ≥ (Σx)²/t  (Cauchy–Schwarz)
        for (s, s2) in out.iter().zip(&out_sq) {
            assert!(*s2 >= s * s / 16.0 - 1e-9);
        }
    }

    #[test]
    fn stage_pull_matches_pull_batch_rng_counter_and_values() {
        // staging + PullEngine::pull_batch must be indistinguishable from
        // ArmSet::pull_batch: same rng draws, same counter charge, same
        // (Σx, Σx²) — this is what makes the multi-query driver
        // bitwise-identical to the per-query path.
        let ds = synthetic::gaussian_iid(6, 32, 8);
        let query = ds.row_vec(0);
        let rows = DenseArms::<ScalarEngine>::candidates(6, Some(0));
        let mut e1 = ScalarEngine;
        let mut a1 =
            DenseArms::new(&ds, &query, &rows, Metric::L2Sq, &mut e1);
        let mut rng1 = Rng::new(77);
        let mut c1 = Counter::new();
        let (mut s1, mut q1) = (Vec::new(), Vec::new());
        a1.pull_batch(&[0, 2, 4], 8, &mut rng1, &mut c1, &mut s1, &mut q1);
        let mut e2 = ScalarEngine;
        let mut a2 =
            DenseArms::new(&ds, &query, &rows, Metric::L2Sq, &mut e2);
        let mut rng2 = Rng::new(77);
        let mut c2 = Counter::new();
        let (rids, coords) = a2.stage_pull(&[0, 2, 4], 8, &mut rng2,
                                           &mut c2);
        drop(a2);
        assert_eq!(rids, vec![rows[0], rows[2], rows[4]]);
        assert_eq!(c1.get(), c2.get());
        assert_eq!(rng1.next_u64(), rng2.next_u64(), "rng streams diverged");
        let req =
            PullRequest { query: &query, rows: &rids, coord_ids: &coords };
        let (mut s2, mut q2) = (Vec::new(), Vec::new());
        ScalarEngine.pull_batch(&ds, &[req], Metric::L2Sq, &mut s2,
                                &mut q2);
        assert_eq!(s1, s2);
        assert_eq!(q1, q2);
    }

    #[test]
    fn dense_exact_matches_dataset_dist() {
        let ds = synthetic::gaussian_iid(5, 32, 5);
        let mut engine = ScalarEngine;
        let query = ds.row_vec(2);
        let rows = DenseArms::<ScalarEngine>::candidates(5, Some(2));
        let mut arms =
            DenseArms::new(&ds, &query, &rows, Metric::L2Sq, &mut engine);
        let mut c = Counter::new();
        // arm 0 maps to dataset row 0
        let got = arms.exact_mean(0, &mut c) * 32.0;
        let want = ds.dist(2, 0, Metric::L2Sq, &mut Counter::new());
        assert!((got - want).abs() < 1e-6);
        assert_eq!(c.get(), 32);
    }

    #[test]
    fn sparse_box_is_unbiased_property() {
        // Eq. 12's unbiasedness: MC average converges to θ for random
        // sparse rows (Appendix C-A).
        proptest::check(10, |rng| {
            let d = 32 + rng.below(32);
            let mk_row = |rng: &mut Rng| -> Vec<(u32, f32)> {
                let mut row = Vec::new();
                for j in 0..d {
                    if rng.bool(0.25) {
                        row.push((j as u32, 1.0 + rng.f32()));
                    }
                }
                row
            };
            let rows = vec![mk_row(rng), mk_row(rng)];
            let ds = SparseDataset::from_rows(2, d, rows);
            if ds.nnz(0) + ds.nnz(1) == 0 {
                return Ok(());
            }
            let mut arms = SparseArms::new(&ds, 0, &[1], Metric::L1);
            let mut c = Counter::new();
            let theta = arms.exact_mean(0, &mut c);
            let t = 60_000u64;
            let est = arms.pull(0, t, rng, &mut c).0 / t as f64;
            crate::prop_assert!(
                (est - theta).abs() < 0.08 * theta.max(0.01),
                "sparse est {est} vs theta {theta} (d={d})"
            );
            Ok(())
        });
    }

    #[test]
    fn sparse_max_pulls_tracks_supports() {
        let ds = SparseDataset::from_rows(
            3,
            16,
            vec![
                vec![(0, 1.0), (1, 1.0)],
                vec![(2, 5.0)],
                vec![],
            ],
        );
        let arms = SparseArms::new(&ds, 0, &[1, 2], Metric::L1);
        assert_eq!(arms.max_pulls(0), 3); // 2 + 1
        assert_eq!(arms.max_pulls(1), 2); // 2 + 0, max(1) applies at 0+0 only
    }

    #[test]
    fn sparse_empty_pair_is_zero() {
        let ds = SparseDataset::from_rows(2, 8, vec![vec![], vec![]]);
        let mut arms = SparseArms::new(&ds, 0, &[1], Metric::L1);
        let mut rng = Rng::new(7);
        let mut c = Counter::new();
        assert_eq!(arms.pull(0, 10, &mut rng, &mut c), (0.0, 0.0));
        assert_eq!(arms.exact_mean(0, &mut c), 0.0);
    }

    #[test]
    fn submit_complete_split_matches_blocking_calls_bitwise() {
        // the default (eager) split API must be indistinguishable from
        // the blocking calls on every wave kind, and tickets must be
        // completable out of submission order
        let ds = synthetic::gaussian_iid(10, 24, 12);
        let q1 = ds.row_vec(0);
        let q2 = ds.row_vec(1);
        let rows: Vec<u32> = (0..10).collect();
        let coords = vec![0u32, 5, 5, 23];
        let mut eng = ScalarEngine;
        let (mut s0, mut sq0) = (Vec::new(), Vec::new());
        eng.partial_sums(&ds, &q1, &rows, &coords, Metric::L2Sq, &mut s0,
                         &mut sq0);
        let t = eng.submit_partial_sums(&ds, &q1, &rows, &coords,
                                        Metric::L2Sq);
        let (mut s1, mut sq1) = (Vec::new(), Vec::new());
        eng.complete_sums(t, &mut s1, &mut sq1);
        assert_eq!(s0, s1);
        assert_eq!(sq0, sq1);
        // two tickets in flight, completed in reverse order
        let ta = eng.submit_exact_dists(&ds, &q1, &rows, Metric::L1);
        let tb = eng.submit_exact_dists(&ds, &q2, &rows, Metric::L1);
        let (mut da, mut db) = (Vec::new(), Vec::new());
        eng.complete_dists(tb, &mut db);
        eng.complete_dists(ta, &mut da);
        let (mut wa, mut wb) = (Vec::new(), Vec::new());
        eng.exact_dists(&ds, &q1, &rows, Metric::L1, &mut wa);
        eng.exact_dists(&ds, &q2, &rows, Metric::L1, &mut wb);
        assert_eq!(da, wa);
        assert_eq!(db, wb);
        // pull_batch ticket
        let req = PullRequest { query: &q1, rows: &rows,
                                coord_ids: &coords };
        let (mut bs0, mut bq0) = (Vec::new(), Vec::new());
        eng.pull_batch(&ds, &[req], Metric::L1, &mut bs0, &mut bq0);
        let t = eng.submit_pull_batch(&ds, &[req], Metric::L1);
        let (mut bs1, mut bq1) = (Vec::new(), Vec::new());
        eng.complete_sums(t, &mut bs1, &mut bq1);
        assert_eq!(bs0, bs1);
        assert_eq!(bq0, bq1);
        assert!(!eng.pipelined(), "scalar engine resolves at submit");
    }

    #[test]
    fn abandon_wave_discards_eager_tickets_without_side_effects() {
        // abandoning must not disturb other in-flight tickets, and an
        // eager engine stays fully usable afterwards
        let ds = synthetic::gaussian_iid(6, 16, 19);
        let q = ds.row_vec(0);
        let rows: Vec<u32> = (1..6).collect();
        let coords = vec![0u32, 3, 15];
        let mut eng = ScalarEngine;
        let keep = eng.submit_partial_sums(&ds, &q, &rows, &coords,
                                           Metric::L2Sq);
        let toss = eng.submit_partial_sums(&ds, &q, &rows, &coords,
                                           Metric::L1);
        eng.abandon_wave(toss);
        let (mut s, mut sq) = (Vec::new(), Vec::new());
        eng.complete_sums(keep, &mut s, &mut sq);
        let (mut ws, mut wsq) = (Vec::new(), Vec::new());
        eng.partial_sums(&ds, &q, &rows, &coords, Metric::L2Sq, &mut ws,
                         &mut wsq);
        assert_eq!(s, ws);
        assert_eq!(sq, wsq);
        // boxed forwarding line
        let mut boxed: Box<dyn PullEngine + Send> = Box::new(ScalarEngine);
        let t = boxed.submit_exact_dists(&ds, &q, &rows, Metric::L1);
        boxed.abandon_wave(t);
    }

    #[test]
    fn boxed_engine_forwards_the_split_api() {
        let ds = synthetic::gaussian_iid(6, 8, 3);
        let q = ds.row_vec(0);
        let rows: Vec<u32> = (0..6).collect();
        let mut boxed: Box<dyn PullEngine + Send> = Box::new(ScalarEngine);
        assert!(!boxed.pipelined());
        let t = boxed.submit_exact_dists(&ds, &q, &rows, Metric::L2Sq);
        let mut got = Vec::new();
        boxed.complete_dists(t, &mut got);
        let mut want = Vec::new();
        ScalarEngine.exact_dists(&ds, &q, &rows, Metric::L2Sq, &mut want);
        assert_eq!(got, want);
    }

    #[test]
    fn candidates_excludes_query() {
        let rows = DenseArms::<ScalarEngine>::candidates(5, Some(2));
        assert_eq!(rows, vec![0, 1, 3, 4]);
        let all = DenseArms::<ScalarEngine>::candidates(3, None);
        assert_eq!(all, vec![0, 1, 2]);
    }
}
