//! BMO-NN — Algorithm 2: k-nearest-neighbor queries and full k-NN-graph
//! construction via BMO UCB over the Monte Carlo boxes.

use crate::coordinator::arms::{ArmSet, DenseArms, PullEngine, SparseArms};
use crate::coordinator::bandit::{run_bmo_ucb, BanditParams};
use crate::data::dense::{DenseDataset, Metric};
use crate::data::sparse::SparseDataset;
use crate::metrics::{Counter, RunMetrics};
use crate::util::rng::Rng;

/// One k-NN answer: neighbor dataset ids, ordered by increasing distance,
/// with the bandit's final (normalized θ·d, i.e. un-normalized distance)
/// estimates and the run's cost accounting.
#[derive(Clone, Debug)]
pub struct KnnResult {
    pub ids: Vec<u32>,
    /// un-normalized distance estimates (exact when the arm was
    /// exact-evaluated; high-accuracy estimates otherwise)
    pub dists: Vec<f64>,
    pub metrics: RunMetrics,
}

/// k-NN of an in-dataset point `q` (self excluded) — dense box.
pub fn knn_point_dense<E: PullEngine>(
    data: &DenseDataset,
    q: usize,
    metric: Metric,
    params: &BanditParams,
    engine: &mut E,
    rng: &mut Rng,
    counter: &mut Counter,
) -> KnnResult {
    let query = data.row_vec(q);
    knn_dense_inner(data, query, Some(q), metric, params, engine, rng, counter)
}

/// k-NN of an external query vector — dense box.
pub fn knn_query_dense<E: PullEngine>(
    data: &DenseDataset,
    query: &[f32],
    metric: Metric,
    params: &BanditParams,
    engine: &mut E,
    rng: &mut Rng,
    counter: &mut Counter,
) -> KnnResult {
    knn_dense_inner(data, query.to_vec(), None, metric, params, engine, rng,
                    counter)
}

fn knn_dense_inner<E: PullEngine>(
    data: &DenseDataset,
    query: Vec<f32>,
    exclude: Option<usize>,
    metric: Metric,
    params: &BanditParams,
    engine: &mut E,
    rng: &mut Rng,
    counter: &mut Counter,
) -> KnnResult {
    let rows = DenseArms::<E>::candidates(data.n, exclude);
    let d = data.d as f64;
    let mut arms = DenseArms::new(data, query, rows, metric, engine);
    let res = run_bmo_ucb(&mut arms, params.clone(), rng, counter);
    KnnResult {
        ids: res.best.iter().map(|&(a, _)| arms.arm_id(a)).collect(),
        dists: res.best.iter().map(|&(_, th)| th * d).collect(),
        metrics: res.metrics,
    }
}

/// k-NN of an in-dataset point — sparse box (§IV-A).
pub fn knn_point_sparse(
    data: &SparseDataset,
    q: usize,
    metric: Metric,
    params: &BanditParams,
    rng: &mut Rng,
    counter: &mut Counter,
) -> KnnResult {
    let rows: Vec<u32> = (0..data.n as u32)
        .filter(|&i| i as usize != q)
        .collect();
    let d = data.d as f64;
    let mut arms = SparseArms::new(data, q, rows, metric);
    let res = run_bmo_ucb(&mut arms, params.clone(), rng, counter);
    KnnResult {
        ids: res.best.iter().map(|&(a, _)| arms.arm_id(a)).collect(),
        dists: res.best.iter().map(|&(_, th)| th * d).collect(),
        metrics: res.metrics,
    }
}

/// Full k-NN graph (Algorithm 2's outer loop): the k nearest neighbors of
/// every point. δ is split as δ/n per query, matching line 4 of Alg 2.
pub struct GraphResult {
    pub neighbors: Vec<Vec<u32>>,
    pub metrics: RunMetrics,
}

pub fn knn_graph_dense<E: PullEngine>(
    data: &DenseDataset,
    metric: Metric,
    params: &BanditParams,
    engine: &mut E,
    rng: &mut Rng,
    counter: &mut Counter,
) -> GraphResult {
    let mut per_query = params.clone();
    per_query.delta = params.delta / data.n as f64;
    let mut neighbors = Vec::with_capacity(data.n);
    let mut metrics = RunMetrics::default();
    for q in 0..data.n {
        let mut qrng = rng.fork(q as u64);
        let res = knn_point_dense(data, q, metric, &per_query, engine,
                                  &mut qrng, counter);
        metrics.merge(&res.metrics);
        neighbors.push(res.ids);
    }
    GraphResult { neighbors, metrics }
}

pub fn knn_graph_sparse(
    data: &SparseDataset,
    metric: Metric,
    params: &BanditParams,
    rng: &mut Rng,
    counter: &mut Counter,
) -> GraphResult {
    let mut per_query = params.clone();
    per_query.delta = params.delta / data.n as f64;
    let mut neighbors = Vec::with_capacity(data.n);
    let mut metrics = RunMetrics::default();
    for q in 0..data.n {
        let mut qrng = rng.fork(q as u64);
        let res = knn_point_sparse(data, q, metric, &per_query, &mut qrng,
                                   counter);
        metrics.merge(&res.metrics);
        neighbors.push(res.ids);
    }
    GraphResult { neighbors, metrics }
}

/// Generic wrapper mirroring Alg 2 over any custom [`ArmSet`] — this is
/// the "tailor problem-specific Monte Carlo boxes" extension point the
/// paper describes (§III).
pub fn knn_arms<A: ArmSet>(
    arms: &mut A,
    params: &BanditParams,
    rng: &mut Rng,
    counter: &mut Counter,
) -> KnnResult {
    let res = run_bmo_ucb(arms, params.clone(), rng, counter);
    KnnResult {
        ids: res.best.iter().map(|&(a, _)| arms.arm_id(a)).collect(),
        dists: res.best.iter().map(|&(_, th)| th).collect(),
        metrics: res.metrics,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines;
    use crate::coordinator::arms::ScalarEngine;
    use crate::coordinator::bandit::PullPolicy;
    use crate::data::synthetic;

    fn params(k: usize) -> BanditParams {
        BanditParams { k, delta: 0.01, policy: PullPolicy::batched(),
                       ..Default::default() }
    }

    #[test]
    fn dense_point_query_matches_bruteforce() {
        let ds = synthetic::image_like(80, 512, 21);
        let mut c = Counter::new();
        let truth = baselines::exact::knn_point(
            &ds, 3, 5, Metric::L2Sq, &mut Counter::new());
        let mut engine = ScalarEngine;
        let mut rng = Rng::new(22);
        let res = knn_point_dense(&ds, 3, Metric::L2Sq, &params(5),
                                  &mut engine, &mut rng, &mut c);
        let got: std::collections::HashSet<_> = res.ids.iter().collect();
        let want: std::collections::HashSet<_> = truth.ids.iter().collect();
        assert_eq!(got, want);
        assert!(!res.ids.contains(&3), "self must be excluded");
    }

    #[test]
    fn external_query_works() {
        let ds = synthetic::image_like(60, 256, 23);
        let q: Vec<f32> = ds.row_vec(7).iter().map(|v| v + 0.001).collect();
        let mut engine = ScalarEngine;
        let mut rng = Rng::new(24);
        let mut c = Counter::new();
        let res = knn_query_dense(&ds, &q, Metric::L2Sq, &params(1),
                                  &mut engine, &mut rng, &mut c);
        assert_eq!(res.ids[0], 7);
    }

    #[test]
    fn sparse_query_matches_bruteforce() {
        let ds = synthetic::rna_like(60, 800, 0.08, 25);
        let mut rng = Rng::new(26);
        let mut c = Counter::new();
        let res = knn_point_sparse(&ds, 0, Metric::L1, &params(3), &mut rng,
                                   &mut c);
        // brute force
        let mut truth: Vec<(f64, u32)> = (1..ds.n)
            .map(|i| (ds.dist(0, i, Metric::L1, &mut Counter::new()),
                      i as u32))
            .collect();
        truth.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let want: std::collections::HashSet<u32> =
            truth[..3].iter().map(|&(_, i)| i).collect();
        let got: std::collections::HashSet<u32> =
            res.ids.iter().copied().collect();
        assert_eq!(got, want);
    }

    #[test]
    fn graph_construction_high_accuracy() {
        let ds = synthetic::image_like(40, 256, 27);
        let mut engine = ScalarEngine;
        let mut rng = Rng::new(28);
        let mut c = Counter::new();
        let g = knn_graph_dense(&ds, Metric::L2Sq, &params(3), &mut engine,
                                &mut rng, &mut c);
        assert_eq!(g.neighbors.len(), 40);
        let mut correct = 0;
        for q in 0..40 {
            let truth = baselines::exact::knn_point(
                &ds, q, 3, Metric::L2Sq, &mut Counter::new());
            let got: std::collections::HashSet<_> =
                g.neighbors[q].iter().collect();
            let want: std::collections::HashSet<_> = truth.ids.iter().collect();
            if got == want {
                correct += 1;
            }
        }
        assert!(correct >= 39, "accuracy {correct}/40");
    }

    #[test]
    fn dists_are_sorted_and_close_to_truth() {
        let ds = synthetic::image_like(50, 512, 29);
        let mut engine = ScalarEngine;
        let mut rng = Rng::new(30);
        let mut c = Counter::new();
        let res = knn_point_dense(&ds, 0, Metric::L2Sq, &params(5),
                                  &mut engine, &mut rng, &mut c);
        for w in res.dists.windows(2) {
            assert!(w[0] <= w[1] + 1e-6, "dists not sorted: {:?}", res.dists);
        }
        // each reported distance should be near the true distance
        for (&id, &dist) in res.ids.iter().zip(&res.dists) {
            let truth = ds.dist(0, id as usize, Metric::L2Sq,
                                &mut Counter::new());
            assert!((dist - truth).abs() < 0.2 * truth.max(1.0),
                    "dist {dist} vs truth {truth}");
        }
    }
}
