//! BMO-NN — Algorithm 2: k-nearest-neighbor queries and full k-NN-graph
//! construction via BMO UCB over the Monte Carlo boxes.
//!
//! Two execution modes:
//!
//! * **Per-query** ([`knn_point_dense`] / [`knn_query_dense`] /
//!   [`knn_point_sparse`]): one bandit run to completion.
//! * **Batched multi-query** ([`knn_batch_dense`],
//!   [`knn_batch_points_dense`], [`knn_batch_sparse`]): many concurrent
//!   [`BmoUcb`] instances advanced in lockstep rounds over the shared
//!   dataset, with every instance's staged coordinate pulls coalesced into
//!   a single [`PullEngine::pull_batch`] pass per round — each data block
//!   is swept once per round instead of once per query. On a *pipelined*
//!   engine (the multiplexed remote ring) the round's wave is submitted
//!   first and the drivers overlap per-query result emission with the
//!   in-flight round trip (`submit_pull_batch` / `complete_sums`); the
//!   scheduling, rng streams and outputs are identical either way. With
//!   [`BatchOptions::speculate`] the drivers additionally pipeline
//!   *across* rounds on a pipelined engine: a predicted round-t+1 wave
//!   is submitted before round t retires and confirmed or discarded
//!   when the real round t+1 is staged — same answers, less wall-clock
//!   (see [`SpecStats`] and `BmoUcb::predict_next_pull`).
//!   Query `i` of a
//!   batch is answered with the rng stream `rng.fork(i as u64)` and is
//!   bitwise-identical to the per-query path under that same stream, for
//!   any batch size (the equivalence is pinned by `tests/property_knn`).
//!   The query server and graph construction both run on this driver.
//!
//! Both modes are generic over the [`PullEngine`], so the same drivers
//! run single-threaded (`NativeEngine`/`ScalarEngine`), multi-core
//! (`runtime::sharded::ShardedEngine`, selected via `[engine] shards` /
//! `--shards` — per-wave row-sharding that is bitwise-identical to
//! single-threaded execution), on a replicated shard-server ring
//! (`runtime::remote::RemoteEngine`), or on the PJRT artifact path.
//!
//! **Degraded mode.** Before running the bandit, the dense drivers ask
//! the engine for its [`Coverage`]. Local engines always report full
//! coverage; a remote ring in degraded mode (`--degraded`) reports the
//! surviving row ranges while some shard has no live replica. Under
//! partial coverage the drivers skip the bandit entirely and answer
//! **exact** top-k over the surviving rows (adaptive sampling needs
//! every arm reachable; exact distances over the covered subset is the
//! strongest answer the surviving data supports), annotating each
//! [`KnnResult`] with the coverage so callers — and the query server's
//! JSON clients — can tell a partial answer from a full one.

#![deny(missing_docs)]

use std::time::Instant;

use crate::coordinator::arms::{ArmSet, Coverage, DenseArms, PullEngine,
                               PullRequest, SparseArms, WaveTicket};
use crate::coordinator::bandit::{run_bmo_ucb, BanditParams, BmoUcb,
                                 RoundAction};
use crate::data::dense::{DenseDataset, Metric};
use crate::data::sparse::SparseDataset;
use crate::metrics::{Counter, RunMetrics};
use crate::util::rng::Rng;

/// One k-NN answer: neighbor dataset ids, ordered by increasing distance,
/// with the bandit's final (normalized θ·d, i.e. un-normalized distance)
/// estimates and the run's cost accounting.
///
/// `metrics.dist_computations` is exact per-query in every mode. In the
/// batched drivers `metrics.elapsed` is the query's *in-flight wall time*
/// (first round → emission, spanning the whole lockstep wave), i.e. a
/// latency, not an exclusive-compute time — summing it across a batch
/// overcounts wall clock.
#[derive(Clone, Debug)]
pub struct KnnResult {
    /// neighbor dataset row ids, ordered by increasing distance
    pub ids: Vec<u32>,
    /// un-normalized distance estimates (exact when the arm was
    /// exact-evaluated; high-accuracy estimates otherwise)
    pub dists: Vec<f64>,
    /// cost accounting for this query's run
    pub metrics: RunMetrics,
    /// `None` for a full answer; `Some(coverage)` when the engine could
    /// only reach part of the dataset (degraded remote ring) — then
    /// `ids`/`dists` are the exact top-k over the covered rows only,
    /// and `coverage.fraction()` is the answered share of the dataset
    pub coverage: Option<Coverage>,
}

/// k-NN of an in-dataset point `q` (self excluded) — dense box.
pub fn knn_point_dense<E: PullEngine>(
    data: &DenseDataset,
    q: usize,
    metric: Metric,
    params: &BanditParams,
    engine: &mut E,
    rng: &mut Rng,
    counter: &mut Counter,
) -> KnnResult {
    let query = data.row_vec(q);
    knn_dense_inner(data, &query, Some(q), metric, params, engine, rng,
                    counter)
}

/// k-NN of an external query vector — dense box.
pub fn knn_query_dense<E: PullEngine>(
    data: &DenseDataset,
    query: &[f32],
    metric: Metric,
    params: &BanditParams,
    engine: &mut E,
    rng: &mut Rng,
    counter: &mut Counter,
) -> KnnResult {
    knn_dense_inner(data, query, None, metric, params, engine, rng, counter)
}

fn knn_dense_inner<E: PullEngine>(
    data: &DenseDataset,
    query: &[f32],
    exclude: Option<usize>,
    metric: Metric,
    params: &BanditParams,
    engine: &mut E,
    rng: &mut Rng,
    counter: &mut Counter,
) -> KnnResult {
    if let Some(cov) = engine.coverage() {
        let mut res = knn_degraded_dense(data, &[query], &[exclude],
                                         metric, params.k, engine, &cov,
                                         counter);
        return res.pop().unwrap();
    }
    let rows = DenseArms::<E>::candidates(data.n, exclude);
    let d = data.d as f64;
    // approximate engines (quantized tier) report a worst-case estimate
    // bias; widening the confidence half-widths by it keeps UCB/LCB
    // valid bounds on the true θ, so the run's guarantees hold
    let mut params = params.clone();
    params.bias = params.bias.max(engine.quant_bias(data, query, metric));
    let mut arms = DenseArms::new(data, query, &rows, metric, engine);
    let res = run_bmo_ucb(&mut arms, params, rng, counter);
    KnnResult {
        ids: res.best.iter().map(|&(a, _)| arms.arm_id(a)).collect(),
        dists: res.best.iter().map(|&(_, th)| th * d).collect(),
        metrics: res.metrics,
        coverage: None,
    }
}

/// Degraded-mode answer (see the module docs): the exact top-k of each
/// query over the engine's surviving rows only, each result annotated
/// with the coverage. Returns fewer than `k` neighbors when fewer
/// candidate rows survive. Units are charged at the exact-scan rate
/// (covered rows × d per query).
fn knn_degraded_dense<E: PullEngine, Q: AsRef<[f32]>>(
    data: &DenseDataset,
    queries: &[Q],
    excludes: &[Option<usize>],
    metric: Metric,
    k: usize,
    engine: &mut E,
    cov: &Coverage,
    counter: &mut Counter,
) -> Vec<KnnResult> {
    let mut results = Vec::with_capacity(queries.len());
    let mut dists = Vec::new();
    for (qi, q) in queries.iter().enumerate() {
        let q = q.as_ref();
        assert_eq!(q.len(), data.d, "query {qi} has wrong dimension");
        let t0 = Instant::now();
        let rows: Vec<u32> = cov
            .live
            .iter()
            .flat_map(|&(a, b)| a..b)
            .filter(|&r| Some(r as usize) != excludes[qi])
            .collect();
        if !rows.is_empty() {
            engine.exact_dists(data, q, &rows, metric, &mut dists);
        } else {
            dists.clear();
        }
        let units = (rows.len() * data.d) as u64;
        counter.add(units);
        let mut order: Vec<usize> = (0..rows.len()).collect();
        order.sort_by(|&x, &y| {
            dists[x].total_cmp(&dists[y]).then(rows[x].cmp(&rows[y]))
        });
        order.truncate(k.min(rows.len()));
        results.push(KnnResult {
            ids: order.iter().map(|&j| rows[j]).collect(),
            dists: order.iter().map(|&j| dists[j]).collect(),
            metrics: RunMetrics {
                dist_computations: units,
                rounds: 0,
                exact_evals: rows.len() as u64,
                elapsed: t0.elapsed(),
            },
            coverage: Some(cov.clone()),
        });
    }
    results
}

/// Per-batch execution options of the dense lockstep drivers.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchOptions {
    /// absolute per-batch deadline budget (see
    /// [`knn_batch_dense_deadline`]); `None` = unbounded
    pub deadline: Option<Instant>,
    /// speculative cross-round pipelining: submit a predicted round-t+1
    /// wave before round t retires, confirm or discard it when the real
    /// round t+1 is staged. Only effective on a pipelined engine
    /// ([`PullEngine::pipelined`]); answers are bitwise-identical either
    /// way, speculation only moves wall-clock.
    pub speculate: bool,
}

/// Speculation accounting of one batch: how many speculated per-query
/// pulls were submitted, and of those how many were confirmed (their
/// results consumed in place of a real wave slot) vs discarded
/// (prediction missed; wave abandoned). `speculated ==
/// confirmed + discarded` always holds once a batch returns.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpecStats {
    /// speculated per-query pulls submitted ahead of confirmation
    pub speculated: u64,
    /// speculated pulls whose prediction matched the real round and
    /// whose results were folded into arm state
    pub confirmed: u64,
    /// speculated pulls discarded because the real round diverged
    pub discarded: u64,
}

impl SpecStats {
    /// Fold another batch's counters into this one.
    pub fn merge(&mut self, other: &SpecStats) {
        self.speculated += other.speculated;
        self.confirmed += other.confirmed;
        self.discarded += other.discarded;
    }
}

/// One query's staged pull within a multi-query round.
struct StagedPull {
    slot: usize,
    rows: Vec<u32>,
    coords: Vec<u32>,
}

/// One query's speculated round-t+1 pull inside an in-flight speculative
/// wave: the predicted superset rows, the coordinate draws its rng lane
/// produced, and this entry's offset into the wave's result buffers.
struct SpecEntry {
    slot: usize,
    rows: Vec<u32>,
    coords: Vec<u32>,
    off: usize,
}

/// A speculative wave in flight: the engine ticket plus the per-query
/// entries needed to confirm or discard it next round.
struct SpecWave {
    ticket: WaveTicket,
    entries: Vec<SpecEntry>,
}

/// Per-query state of the batch driver (the query vector itself stays in
/// the caller's slice — no per-slot copy).
struct DenseSlot {
    rows: Vec<u32>,
    bandit: BmoUcb,
    rng: Rng,
    counter: Counter,
    done: bool,
}

/// Batched k-NN for external query vectors (the server's request path).
///
/// Advances one [`BmoUcb`] per query in lockstep rounds; per round, every
/// live query's uniform pull is staged ([`DenseArms::stage_pull`]) and the
/// whole wave is resolved with a single [`PullEngine::pull_batch`] call,
/// so the engine sweeps each data block once per round instead of once
/// per query. Query `i` uses the rng stream `rng.fork(i as u64)` and its
/// answer (ids, dists, unit count) is bitwise-identical to
/// `knn_query_dense(data, &queries[i], .., &mut rng.fork(i), ..)` for any
/// batch size. Per-query units are merged into `counter`.
pub fn knn_batch_dense<E: PullEngine, Q: AsRef<[f32]>>(
    data: &DenseDataset,
    queries: &[Q],
    metric: Metric,
    params: &BanditParams,
    engine: &mut E,
    rng: &mut Rng,
    counter: &mut Counter,
) -> Vec<KnnResult> {
    knn_batch_dense_deadline(data, queries, metric, params, engine, rng,
                             counter, None)
}

/// [`knn_batch_dense`] under an absolute per-batch deadline budget (the
/// query server's `deadline_ms` path). The budget is handed to the
/// engine ([`PullEngine::set_deadline`]) so an engine with real I/O
/// bounds each wave by the *remaining* budget, and it is re-checked
/// between lockstep rounds for every engine. On expiry the driver
/// panics with a message matched by
/// `crate::runtime::wire::is_deadline_error` — the query server's
/// `catch_unwind` turns that into a structured `deadline_exceeded`
/// answer instead of a worker stall. `None` is exactly
/// [`knn_batch_dense`].
#[allow(clippy::too_many_arguments)]
pub fn knn_batch_dense_deadline<E: PullEngine, Q: AsRef<[f32]>>(
    data: &DenseDataset,
    queries: &[Q],
    metric: Metric,
    params: &BanditParams,
    engine: &mut E,
    rng: &mut Rng,
    counter: &mut Counter,
    deadline: Option<Instant>,
) -> Vec<KnnResult> {
    knn_batch_dense_opts(data, queries, metric, params, engine, rng,
                         counter, BatchOptions { deadline, speculate: false })
        .0
}

/// [`knn_batch_dense_deadline`] with full [`BatchOptions`] (deadline +
/// speculative pipelining) and per-batch [`SpecStats`] accounting.
/// `BatchOptions::default()` is exactly [`knn_batch_dense`].
#[allow(clippy::too_many_arguments)]
pub fn knn_batch_dense_opts<E: PullEngine, Q: AsRef<[f32]>>(
    data: &DenseDataset,
    queries: &[Q],
    metric: Metric,
    params: &BanditParams,
    engine: &mut E,
    rng: &mut Rng,
    counter: &mut Counter,
    opts: BatchOptions,
) -> (Vec<KnnResult>, SpecStats) {
    let excludes = vec![None; queries.len()];
    knn_batch_dense_rngs(data, queries, &excludes, metric, params, engine,
                         BatchRngs::Forked(rng), counter, opts)
}

/// [`knn_batch_dense_deadline`] with **content-derived rng streams**:
/// query `i` runs under `Rng::new(seeds[i])` instead of `rng.fork(i)`.
///
/// This is the query server's driver. With a caller-supplied seed
/// derived from the request content (query bits + k), a query's answer
/// — ids, dists *and* unit count — is bitwise-identical no matter
/// which worker computes it, which batch it lands in, or which other
/// queries share its lockstep wave (per-slot rng streams never
/// interact; composition independence is pinned by
/// `seeded_batch_is_composition_independent`). That reproducibility is
/// what lets the serving-layer result cache promise that a cache hit
/// is byte-identical to a fresh compute.
#[allow(clippy::too_many_arguments)]
pub fn knn_batch_dense_seeded<E: PullEngine, Q: AsRef<[f32]>>(
    data: &DenseDataset,
    queries: &[Q],
    metric: Metric,
    params: &BanditParams,
    engine: &mut E,
    seeds: &[u64],
    counter: &mut Counter,
    deadline: Option<Instant>,
) -> Vec<KnnResult> {
    knn_batch_dense_seeded_opts(data, queries, metric, params, engine,
                                seeds, counter,
                                BatchOptions { deadline, speculate: false })
        .0
}

/// [`knn_batch_dense_seeded`] with full [`BatchOptions`] and per-batch
/// [`SpecStats`] — the query server's driver when `[engine] speculate`
/// is on. The per-slot answers are bitwise-identical for every
/// `speculate` setting (the speculative lane draws from a *clone* of
/// the slot rng, so the slot's own stream never moves).
#[allow(clippy::too_many_arguments)]
pub fn knn_batch_dense_seeded_opts<E: PullEngine, Q: AsRef<[f32]>>(
    data: &DenseDataset,
    queries: &[Q],
    metric: Metric,
    params: &BanditParams,
    engine: &mut E,
    seeds: &[u64],
    counter: &mut Counter,
    opts: BatchOptions,
) -> (Vec<KnnResult>, SpecStats) {
    assert_eq!(queries.len(), seeds.len());
    let excludes = vec![None; queries.len()];
    knn_batch_dense_rngs(data, queries, &excludes, metric, params, engine,
                         BatchRngs::Seeded(seeds), counter, opts)
}

/// Batched k-NN for in-dataset points (self excluded) — the figure
/// harness and graph-construction entry point.
pub fn knn_batch_points_dense<E: PullEngine>(
    data: &DenseDataset,
    points: &[usize],
    metric: Metric,
    params: &BanditParams,
    engine: &mut E,
    rng: &mut Rng,
    counter: &mut Counter,
) -> Vec<KnnResult> {
    knn_batch_points_dense_opts(data, points, metric, params, engine, rng,
                                counter, BatchOptions::default())
        .0
}

/// [`knn_batch_points_dense`] with full [`BatchOptions`] and per-batch
/// [`SpecStats`] — the bench harness's speculation rung drives this.
#[allow(clippy::too_many_arguments)]
pub fn knn_batch_points_dense_opts<E: PullEngine>(
    data: &DenseDataset,
    points: &[usize],
    metric: Metric,
    params: &BanditParams,
    engine: &mut E,
    rng: &mut Rng,
    counter: &mut Counter,
    opts: BatchOptions,
) -> (Vec<KnnResult>, SpecStats) {
    // query vectors are the dataset's own rows — borrow, don't copy
    let queries: Vec<&[f32]> =
        points.iter().map(|&q| data.row(q)).collect();
    let excludes: Vec<Option<usize>> =
        points.iter().map(|&q| Some(q)).collect();
    knn_batch_dense_rngs(data, &queries, &excludes, metric, params, engine,
                         BatchRngs::Forked(rng), counter, opts)
}

/// How the batch driver derives query `i`'s private rng stream.
enum BatchRngs<'a> {
    /// The classic contract: `rng.fork(i)` per slot, in slot order.
    /// Bitwise-equal to solo runs under `rng.fork(i)` for any batch
    /// size, but the streams depend on the *parent* rng state and the
    /// slot index, i.e. on batch composition.
    Forked(&'a mut Rng),
    /// Content-derived: `Rng::new(seeds[i])` per slot. The stream
    /// depends only on the caller's seed, so a query's answer is
    /// independent of batch composition — the serving determinism the
    /// result cache relies on.
    Seeded(&'a [u64]),
}

impl BatchRngs<'_> {
    fn stream(&mut self, i: usize) -> Rng {
        match self {
            BatchRngs::Forked(rng) => rng.fork(i as u64),
            BatchRngs::Seeded(seeds) => Rng::new(seeds[i]),
        }
    }
}

/// The lockstep batch driver all dense batch entry points funnel into.
///
/// **Speculative cross-round pipelining** (`opts.speculate`, effective
/// only on a pipelined engine): after round t's real wave is submitted,
/// the driver asks each live bandit for a predicted round-t+1 pull
/// superset ([`BmoUcb::predict_next_pull`]), stages it against a *clone*
/// of the slot's rng (the slot's own stream never moves) and a throwaway
/// counter, and submits the speculative wave before round t retires.
/// When round t+1's real pull is staged, each slot's entry is confirmed
/// iff its coordinate draws match exactly and the real rows are a subset
/// of the speculated rows — then the speculative results are gathered
/// through the row permutation instead of submitting a real wave slot
/// for that query. Mismatched entries are discarded and their wave
/// abandoned ([`PullEngine::abandon_wave`]) without consuming failover
/// attempts or deadline budget. Confirmation relies on the engine
/// contract that per-row results depend only on (row, coords, query,
/// metric) — never on which other rows share the wave — which is what
/// `PullEngine::pull_batch`'s "results as if per-request
/// `partial_sums`" clause already guarantees and the parity matrix
/// pins. Scheduling, rng streams and outputs are bitwise-identical
/// with speculation on or off; only wall-clock moves.
#[allow(clippy::too_many_arguments)]
fn knn_batch_dense_rngs<E: PullEngine, Q: AsRef<[f32]>>(
    data: &DenseDataset,
    queries: &[Q],
    excludes: &[Option<usize>],
    metric: Metric,
    params: &BanditParams,
    engine: &mut E,
    mut rngs: BatchRngs<'_>,
    counter: &mut Counter,
    opts: BatchOptions,
) -> (Vec<KnnResult>, SpecStats) {
    assert_eq!(queries.len(), excludes.len());
    let deadline = opts.deadline;
    // hand the budget to the engine before anything that might touch
    // the network — the coverage probe below must honor it too
    engine.set_deadline(deadline);
    if let Some(cov) = engine.coverage() {
        return (knn_degraded_dense(data, queries, excludes, metric,
                                   params.k, engine, &cov, counter),
                SpecStats::default());
    }
    // speculation needs the submit/complete split to buy overlap; on a
    // blocking engine the flag is structurally inert (no spec wave is
    // ever built), keeping the hot loop byte-for-byte today's behavior
    let speculate = opts.speculate && engine.pipelined();
    let d = data.d as f64;
    let mut slots: Vec<DenseSlot> = Vec::with_capacity(queries.len());
    for (i, q) in queries.iter().enumerate() {
        let q = q.as_ref();
        assert_eq!(q.len(), data.d, "query {i} has wrong dimension");
        let qrng = rngs.stream(i);
        let rows = DenseArms::<E>::candidates(data.n, excludes[i]);
        // same per-query bias widening as the solo driver — quant_bias
        // depends only on (data, query, metric), so the batch stays
        // bitwise-identical to solo runs
        let mut qparams = params.clone();
        qparams.bias =
            qparams.bias.max(engine.quant_bias(data, q, metric));
        let bandit = {
            let arms_view =
                DenseArms::new(data, q, &rows, metric, engine);
            BmoUcb::new(&arms_view, qparams)
        };
        slots.push(DenseSlot {
            rows,
            bandit,
            rng: qrng,
            counter: Counter::new(),
            done: false,
        });
    }
    let mut results: Vec<Option<KnnResult>> =
        (0..slots.len()).map(|_| None).collect();
    let mut remaining = slots.len();
    let (mut out_sum, mut out_sq) = (Vec::new(), Vec::new());
    let (mut spec_sum, mut spec_sq) = (Vec::new(), Vec::new());
    let mut spec_prev: Option<SpecWave> = None;
    let mut stats = SpecStats::default();
    let mut rounds = 0u64;
    while remaining > 0 {
        // between-round budget check: this is what bounds *local*
        // engines too — an engine with real I/O additionally cuts its
        // in-flight waits short via the deadline handed over above.
        // The panic message is matched by
        // `crate::runtime::wire::is_deadline_error`, which is how the
        // query server tells a budget expiry from a real crash.
        if deadline.is_some_and(|dl| Instant::now() >= dl) {
            panic!(
                "deadline exceeded: query budget exhausted after \
                 {rounds} lockstep rounds with {remaining} of {} \
                 queries unfinished",
                slots.len()
            );
        }
        rounds += 1;
        // phase 1: advance every live bandit to its next staged pull (or
        // completion), resolving exact evals and ragged pulls inline;
        // finished queries are only *recorded* here — their results are
        // assembled later, while the round's wave is in flight
        let mut staged: Vec<StagedPull> = Vec::new();
        let mut newly_done: Vec<usize> = Vec::new();
        for (si, slot) in slots.iter_mut().enumerate() {
            if slot.done {
                continue;
            }
            let mut arms = DenseArms::new(data, queries[si].as_ref(),
                                          &slot.rows, metric, engine);
            match slot.bandit.begin_round(&mut arms, &mut slot.rng,
                                          &mut slot.counter) {
                RoundAction::Done => {
                    slot.done = true;
                    newly_done.push(si);
                }
                RoundAction::Pull { t } => {
                    let (rows, coords) = arms.stage_pull(
                        slot.bandit.pending_arms(), t, &mut slot.rng,
                        &mut slot.counter);
                    staged.push(StagedPull { slot: si, rows, coords });
                }
            }
        }
        // phase 1.5 (speculation): match each staged pull against the
        // speculative wave submitted last round. A slot confirms iff
        // its entry's coordinate draws are identical (the slot rng
        // advanced exactly as the speculative clone did — no inline
        // ragged pulls or exact evals intervened) and the real rows are
        // a subset of the speculated superset; `hits[i]` then carries
        // the entry index and the row permutation to gather through.
        // Pure comparison — no I/O, no rng, no arm state touched.
        let hits: Vec<Option<(usize, Vec<usize>)>> = match &spec_prev {
            None => (0..staged.len()).map(|_| None).collect(),
            Some(w) => staged
                .iter()
                .map(|s| {
                    let ei = w.entries
                        .iter()
                        .position(|e| e.slot == s.slot)?;
                    let e = &w.entries[ei];
                    if e.coords != s.coords {
                        return None;
                    }
                    let pos: std::collections::HashMap<u32, usize> = e
                        .rows
                        .iter()
                        .enumerate()
                        .map(|(j, &r)| (r, j))
                        .collect();
                    let mut perm = Vec::with_capacity(s.rows.len());
                    for &r in &s.rows {
                        perm.push(*pos.get(&r)?);
                    }
                    Some((ei, perm))
                })
                .collect(),
        };
        // phase 2: put the coalesced wave on the engine — only the
        // slots the speculation missed (with full confirmation the
        // round needs no real wave at all). A pipelined engine (the
        // remote ring) has every sub-wave on the wire when submit
        // returns, so the per-query bookkeeping below overlaps the
        // network round trip; blocking engines keep the plain call
        // (it reuses the out_sum/out_sq scratch across rounds).
        let misses: Vec<usize> = (0..staged.len())
            .filter(|&i| hits[i].is_none())
            .collect();
        let ticket: Option<WaveTicket> = if misses.is_empty() {
            None
        } else {
            let reqs: Vec<PullRequest> = misses
                .iter()
                .map(|&i| {
                    let s = &staged[i];
                    PullRequest {
                        query: queries[s.slot].as_ref(),
                        rows: &s.rows,
                        coord_ids: &s.coords,
                    }
                })
                .collect();
            if engine.pipelined() {
                Some(engine.submit_pull_batch(data, &reqs, metric))
            } else {
                engine.pull_batch(data, &reqs, metric, &mut out_sum,
                                  &mut out_sq);
                None
            }
        };
        // phase 2.5 (speculation): predict round t+1 and put its wave
        // on the wire while round t is still in flight — this is the
        // overlap that removes the per-round round trip from the
        // critical path. The speculative staging draws from a *clone*
        // of each slot's rng and charges a throwaway counter, so the
        // slot's own stream and accounting never move and
        // speculation-off stays byte-for-byte identical.
        let spec_next: Option<SpecWave> = if speculate && !staged.is_empty()
        {
            let mut entries: Vec<SpecEntry> = Vec::new();
            let mut off = 0usize;
            for s in &staged {
                let slot = &mut slots[s.slot];
                let mut arms = DenseArms::new(data, queries[s.slot].as_ref(),
                                              &slot.rows, metric, engine);
                if let Some((pred, t)) =
                    slot.bandit.predict_next_pull(&arms)
                {
                    let mut spec_rng = slot.rng.clone();
                    let mut scrap = Counter::new();
                    let (rows, coords) = arms.stage_pull(&pred, t,
                                                         &mut spec_rng,
                                                         &mut scrap);
                    let n_rows = rows.len();
                    entries.push(SpecEntry { slot: s.slot, rows, coords,
                                             off });
                    off += n_rows;
                }
            }
            if entries.is_empty() {
                None
            } else {
                let reqs: Vec<PullRequest> = entries
                    .iter()
                    .map(|e| PullRequest {
                        query: queries[e.slot].as_ref(),
                        rows: &e.rows,
                        coord_ids: &e.coords,
                    })
                    .collect();
                let spec_ticket =
                    engine.submit_pull_batch(data, &reqs, metric);
                stats.speculated += entries.len() as u64;
                Some(SpecWave { ticket: spec_ticket, entries })
            }
        } else {
            None
        };
        // overlapped with the in-flight wave: emit the results of the
        // queries that finished this round
        for &si in &newly_done {
            let slot = &slots[si];
            let res = slot.bandit.result(&slot.counter);
            results[si] = Some(KnnResult {
                ids: res.best.iter()
                    .map(|&(a, _)| slot.rows[a])
                    .collect(),
                dists: res.best.iter()
                    .map(|&(_, th)| th * d)
                    .collect(),
                metrics: res.metrics,
                coverage: None,
            });
        }
        // phase 3: collect the waves' replies and scatter them back
        // into each bandit (per-query end_round accounting). Confirmed
        // slots gather from the speculative wave through their row
        // permutation (per-row results are position-independent, see
        // the fn docs); missed slots consume the real wave in order.
        if !staged.is_empty() {
            if let Some(t) = ticket {
                engine.complete_sums(t, &mut out_sum, &mut out_sq);
            }
            let confirmed = hits.iter().flatten().count() as u64;
            let spec_entries: Option<Vec<SpecEntry>> =
                if let Some(w) = spec_prev.take() {
                    stats.confirmed += confirmed;
                    stats.discarded += w.entries.len() as u64 - confirmed;
                    if confirmed > 0 {
                        engine.complete_sums(w.ticket, &mut spec_sum,
                                             &mut spec_sq);
                        Some(w.entries)
                    } else {
                        engine.abandon_wave(w.ticket);
                        None
                    }
                } else {
                    None
                };
            let mut off = 0usize;
            let (mut gsum, mut gsq) = (Vec::new(), Vec::new());
            for (i, s) in staged.iter().enumerate() {
                match &hits[i] {
                    Some((ei, perm)) => {
                        let e = &spec_entries.as_ref().unwrap()[*ei];
                        gsum.clear();
                        gsq.clear();
                        for &j in perm {
                            gsum.push(spec_sum[e.off + j]);
                            gsq.push(spec_sq[e.off + j]);
                        }
                        slots[s.slot].bandit.end_round(&gsum, &gsq);
                    }
                    None => {
                        let m = s.rows.len();
                        slots[s.slot].bandit.end_round(
                            &out_sum[off..off + m],
                            &out_sq[off..off + m]);
                        off += m;
                    }
                }
            }
        } else if let Some(w) = spec_prev.take() {
            // every slot went Done before consuming the speculation:
            // the final round never staged, discard the orphan wave
            stats.discarded += w.entries.len() as u64;
            engine.abandon_wave(w.ticket);
        }
        spec_prev = spec_next;
        remaining = slots.iter().filter(|s| !s.done).count();
    }
    debug_assert!(spec_prev.is_none(),
                  "a speculative wave outlived the lockstep loop");
    if let Some(w) = spec_prev.take() {
        stats.discarded += w.entries.len() as u64;
        engine.abandon_wave(w.ticket);
    }
    for slot in &slots {
        counter.add(slot.counter.get());
    }
    (results.into_iter().map(|r| r.unwrap()).collect(), stats)
}

/// k-NN of an in-dataset point — sparse box (§IV-A).
pub fn knn_point_sparse(
    data: &SparseDataset,
    q: usize,
    metric: Metric,
    params: &BanditParams,
    rng: &mut Rng,
    counter: &mut Counter,
) -> KnnResult {
    let rows: Vec<u32> = (0..data.n as u32)
        .filter(|&i| i as usize != q)
        .collect();
    let d = data.d as f64;
    let mut arms = SparseArms::new(data, q, &rows, metric);
    let res = run_bmo_ucb(&mut arms, params.clone(), rng, counter);
    KnnResult {
        ids: res.best.iter().map(|&(a, _)| arms.arm_id(a)).collect(),
        dists: res.best.iter().map(|&(_, th)| th * d).collect(),
        metrics: res.metrics,
        coverage: None,
    }
}

/// Batched sparse k-NN: many in-dataset query points advanced in lockstep
/// rounds. The sparse Monte Carlo box samples in O(1) per pull with no
/// dense blocks to sweep, so there is no cross-query engine coalescing to
/// do — the driver provides the same lockstep scheduling, rng-forking and
/// accounting contract as [`knn_batch_dense`] (query `i` ≡ per-query run
/// under `rng.fork(i as u64)`, bitwise).
pub fn knn_batch_sparse(
    data: &SparseDataset,
    points: &[usize],
    metric: Metric,
    params: &BanditParams,
    rng: &mut Rng,
    counter: &mut Counter,
) -> Vec<KnnResult> {
    struct SparseSlot {
        point: usize,
        rows: Vec<u32>,
        bandit: BmoUcb,
        rng: Rng,
        counter: Counter,
        done: bool,
    }
    let d = data.d as f64;
    let mut slots: Vec<SparseSlot> = Vec::with_capacity(points.len());
    for (i, &q) in points.iter().enumerate() {
        let qrng = rng.fork(i as u64);
        let rows: Vec<u32> = (0..data.n as u32)
            .filter(|&r| r as usize != q)
            .collect();
        let bandit = {
            let arms_view = SparseArms::new(data, q, &rows, metric);
            BmoUcb::new(&arms_view, params.clone())
        };
        slots.push(SparseSlot {
            point: q,
            rows,
            bandit,
            rng: qrng,
            counter: Counter::new(),
            done: false,
        });
    }
    let mut results: Vec<Option<KnnResult>> =
        (0..slots.len()).map(|_| None).collect();
    let mut remaining = slots.len();
    let (mut sums, mut sqs) = (Vec::new(), Vec::new());
    while remaining > 0 {
        for (si, slot) in slots.iter_mut().enumerate() {
            if slot.done {
                continue;
            }
            let mut arms =
                SparseArms::new(data, slot.point, &slot.rows, metric);
            match slot.bandit.begin_round(&mut arms, &mut slot.rng,
                                          &mut slot.counter) {
                RoundAction::Done => {
                    let res = slot.bandit.result(&slot.counter);
                    results[si] = Some(KnnResult {
                        ids: res.best.iter()
                            .map(|&(a, _)| slot.rows[a])
                            .collect(),
                        dists: res.best.iter()
                            .map(|&(_, th)| th * d)
                            .collect(),
                        metrics: res.metrics,
                        coverage: None,
                    });
                    slot.done = true;
                }
                RoundAction::Pull { t } => {
                    arms.pull_batch(slot.bandit.pending_arms(), t,
                                    &mut slot.rng, &mut slot.counter,
                                    &mut sums, &mut sqs);
                    slot.bandit.end_round(&sums, &sqs);
                }
            }
        }
        remaining = slots.iter().filter(|s| !s.done).count();
    }
    for slot in &slots {
        counter.add(slot.counter.get());
    }
    results.into_iter().map(|r| r.unwrap()).collect()
}

/// Full k-NN graph (Algorithm 2's outer loop): the k nearest neighbors of
/// every point. δ is split as δ/n per query, matching line 4 of Alg 2.
/// `metrics.elapsed` sums per-query in-flight times (see [`KnnResult`]),
/// which exceeds wall clock under the batched driver.
pub struct GraphResult {
    /// `neighbors[i]` = the k nearest neighbors of point `i`
    pub neighbors: Vec<Vec<u32>>,
    /// cost accounting summed over every per-point query
    pub metrics: RunMetrics,
    /// `None` when every wave ran at full coverage; otherwise the
    /// *worst* (fewest surviving rows) coverage any wave was answered
    /// under — the graph's neighbor lists are then restricted to
    /// surviving rows for those waves and callers must surface that
    /// rather than present the graph as complete
    pub coverage: Option<Coverage>,
}

/// Queries per batch wave during graph construction: bounds the driver's
/// resident bandit state (each in-flight query keeps O(n) arm state) while
/// still giving the engine wide coalesced rounds.
const GRAPH_WAVE: usize = 64;

/// Full k-NN graph over a dense dataset, built in batched waves of
/// `GRAPH_WAVE` lockstep queries through [`knn_batch_points_dense`].
pub fn knn_graph_dense<E: PullEngine>(
    data: &DenseDataset,
    metric: Metric,
    params: &BanditParams,
    engine: &mut E,
    rng: &mut Rng,
    counter: &mut Counter,
) -> GraphResult {
    let mut per_query = params.clone();
    per_query.delta = params.delta / data.n as f64;
    let mut neighbors = Vec::with_capacity(data.n);
    let mut metrics = RunMetrics::default();
    let mut coverage: Option<Coverage> = None;
    let mut q = 0;
    while q < data.n {
        let hi = (q + GRAPH_WAVE).min(data.n);
        let points: Vec<usize> = (q..hi).collect();
        let wave = knn_batch_points_dense(data, &points, metric, &per_query,
                                          engine, rng, counter);
        for res in wave {
            metrics.merge(&res.metrics);
            if let Some(c) = res.coverage {
                let worse = match &coverage {
                    None => true,
                    Some(old) => c.rows_live() < old.rows_live(),
                };
                if worse {
                    coverage = Some(c);
                }
            }
            neighbors.push(res.ids);
        }
        q = hi;
    }
    GraphResult { neighbors, metrics, coverage }
}

/// Full k-NN graph over a sparse dataset — the sparse counterpart of
/// [`knn_graph_dense`], on the [`knn_batch_sparse`] driver.
pub fn knn_graph_sparse(
    data: &SparseDataset,
    metric: Metric,
    params: &BanditParams,
    rng: &mut Rng,
    counter: &mut Counter,
) -> GraphResult {
    let mut per_query = params.clone();
    per_query.delta = params.delta / data.n as f64;
    let mut neighbors = Vec::with_capacity(data.n);
    let mut metrics = RunMetrics::default();
    let mut q = 0;
    while q < data.n {
        let hi = (q + GRAPH_WAVE).min(data.n);
        let points: Vec<usize> = (q..hi).collect();
        let wave = knn_batch_sparse(data, &points, metric, &per_query, rng,
                                    counter);
        for res in wave {
            metrics.merge(&res.metrics);
            neighbors.push(res.ids);
        }
        q = hi;
    }
    // the sparse box samples locally — there is no remote substrate to
    // lose coverage on
    GraphResult { neighbors, metrics, coverage: None }
}

/// Generic wrapper mirroring Alg 2 over any custom [`ArmSet`] — this is
/// the "tailor problem-specific Monte Carlo boxes" extension point the
/// paper describes (§III).
pub fn knn_arms<A: ArmSet>(
    arms: &mut A,
    params: &BanditParams,
    rng: &mut Rng,
    counter: &mut Counter,
) -> KnnResult {
    let res = run_bmo_ucb(arms, params.clone(), rng, counter);
    KnnResult {
        ids: res.best.iter().map(|&(a, _)| arms.arm_id(a)).collect(),
        dists: res.best.iter().map(|&(_, th)| th).collect(),
        metrics: res.metrics,
        coverage: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines;
    use crate::coordinator::arms::ScalarEngine;
    use crate::coordinator::bandit::PullPolicy;
    use crate::data::synthetic;

    fn params(k: usize) -> BanditParams {
        BanditParams { k, delta: 0.01, policy: PullPolicy::batched(),
                       ..Default::default() }
    }

    #[test]
    fn dense_point_query_matches_bruteforce() {
        let ds = synthetic::image_like(80, 512, 21);
        let mut c = Counter::new();
        let truth = baselines::exact::knn_point(
            &ds, 3, 5, Metric::L2Sq, &mut Counter::new());
        let mut engine = ScalarEngine;
        let mut rng = Rng::new(22);
        let res = knn_point_dense(&ds, 3, Metric::L2Sq, &params(5),
                                  &mut engine, &mut rng, &mut c);
        let got: std::collections::HashSet<_> = res.ids.iter().collect();
        let want: std::collections::HashSet<_> = truth.ids.iter().collect();
        assert_eq!(got, want);
        assert!(!res.ids.contains(&3), "self must be excluded");
    }

    #[test]
    fn external_query_works() {
        let ds = synthetic::image_like(60, 256, 23);
        let q: Vec<f32> = ds.row_vec(7).iter().map(|v| v + 0.001).collect();
        let mut engine = ScalarEngine;
        let mut rng = Rng::new(24);
        let mut c = Counter::new();
        let res = knn_query_dense(&ds, &q, Metric::L2Sq, &params(1),
                                  &mut engine, &mut rng, &mut c);
        assert_eq!(res.ids[0], 7);
    }

    #[test]
    fn sparse_query_matches_bruteforce() {
        let ds = synthetic::rna_like(60, 800, 0.08, 25);
        let mut rng = Rng::new(26);
        let mut c = Counter::new();
        let res = knn_point_sparse(&ds, 0, Metric::L1, &params(3), &mut rng,
                                   &mut c);
        // brute force
        let mut truth: Vec<(f64, u32)> = (1..ds.n)
            .map(|i| (ds.dist(0, i, Metric::L1, &mut Counter::new()),
                      i as u32))
            .collect();
        truth.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let want: std::collections::HashSet<u32> =
            truth[..3].iter().map(|&(_, i)| i).collect();
        let got: std::collections::HashSet<u32> =
            res.ids.iter().copied().collect();
        assert_eq!(got, want);
    }

    #[test]
    fn graph_construction_high_accuracy() {
        let ds = synthetic::image_like(40, 256, 27);
        let mut engine = ScalarEngine;
        let mut rng = Rng::new(28);
        let mut c = Counter::new();
        let g = knn_graph_dense(&ds, Metric::L2Sq, &params(3), &mut engine,
                                &mut rng, &mut c);
        assert_eq!(g.neighbors.len(), 40);
        let mut correct = 0;
        for q in 0..40 {
            let truth = baselines::exact::knn_point(
                &ds, q, 3, Metric::L2Sq, &mut Counter::new());
            let got: std::collections::HashSet<_> =
                g.neighbors[q].iter().collect();
            let want: std::collections::HashSet<_> = truth.ids.iter().collect();
            if got == want {
                correct += 1;
            }
        }
        assert!(correct >= 39, "accuracy {correct}/40");
    }

    #[test]
    fn knn_batch_dense_single_query_equals_solo_bitwise() {
        let ds = synthetic::image_like(50, 128, 31);
        let q = ds.row_vec(5);
        let mut e1 = ScalarEngine;
        let mut base1 = Rng::new(32);
        let mut r1 = base1.fork(0);
        let mut c1 = Counter::new();
        let solo = knn_query_dense(&ds, &q, Metric::L2Sq, &params(3),
                                   &mut e1, &mut r1, &mut c1);
        let mut e2 = ScalarEngine;
        let mut base2 = Rng::new(32);
        let mut c2 = Counter::new();
        let batch = knn_batch_dense(&ds, &[q.clone()], Metric::L2Sq,
                                    &params(3), &mut e2, &mut base2,
                                    &mut c2);
        assert_eq!(batch.len(), 1);
        assert_eq!(solo.ids, batch[0].ids);
        assert_eq!(solo.dists, batch[0].dists);
        assert_eq!(c1.get(), c2.get());
        assert_eq!(solo.metrics.dist_computations,
                   batch[0].metrics.dist_computations);
    }

    #[test]
    fn batch_deadline_generous_budget_is_bitwise_invisible() {
        let ds = synthetic::image_like(50, 128, 31);
        let q = ds.row_vec(5);
        let mut e1 = ScalarEngine;
        let mut b1 = Rng::new(32);
        let mut c1 = Counter::new();
        let plain = knn_batch_dense(&ds, &[q.clone()], Metric::L2Sq,
                                    &params(3), &mut e1, &mut b1,
                                    &mut c1);
        let mut e2 = ScalarEngine;
        let mut b2 = Rng::new(32);
        let mut c2 = Counter::new();
        let dl = Some(Instant::now() + std::time::Duration::from_secs(600));
        let budgeted = knn_batch_dense_deadline(&ds, &[q], Metric::L2Sq,
                                                &params(3), &mut e2,
                                                &mut b2, &mut c2, dl);
        assert_eq!(plain[0].ids, budgeted[0].ids);
        assert_eq!(plain[0].dists, budgeted[0].dists);
        assert_eq!(c1.get(), c2.get());
    }

    #[test]
    fn batch_deadline_expired_budget_panics_classifiably() {
        let ds = synthetic::image_like(30, 64, 41);
        let q = ds.row_vec(2);
        let dl = Some(Instant::now() - std::time::Duration::from_millis(1));
        let payload = std::panic::catch_unwind(move || {
            let mut engine = ScalarEngine;
            let mut rng = Rng::new(42);
            let mut c = Counter::new();
            knn_batch_dense_deadline(&ds, &[q], Metric::L2Sq, &params(2),
                                     &mut engine, &mut rng, &mut c, dl)
        })
        .expect_err("an expired budget must abort the batch");
        let msg = payload
            .downcast_ref::<String>()
            .expect("budget panics carry a String payload");
        assert!(crate::runtime::wire::is_deadline_error(msg),
                "not classifiable as a deadline error: {msg}");
    }

    #[test]
    fn knn_batch_points_dense_matches_bruteforce() {
        let ds = synthetic::image_like(70, 512, 33);
        let points: Vec<usize> = (0..10).map(|i| i * 5).collect();
        let mut engine = ScalarEngine;
        let mut rng = Rng::new(34);
        let mut c = Counter::new();
        let batch = knn_batch_points_dense(&ds, &points, Metric::L2Sq,
                                           &params(4), &mut engine,
                                           &mut rng, &mut c);
        assert_eq!(batch.len(), points.len());
        for (&q, res) in points.iter().zip(&batch) {
            let truth = baselines::exact::knn_point(
                &ds, q, 4, Metric::L2Sq, &mut Counter::new());
            let got: std::collections::HashSet<_> = res.ids.iter().collect();
            let want: std::collections::HashSet<_> =
                truth.ids.iter().collect();
            assert_eq!(got, want, "query {q}");
            assert!(!res.ids.contains(&(q as u32)), "self must be excluded");
        }
        // shared counter must equal the sum of per-query accounting
        let total: u64 =
            batch.iter().map(|r| r.metrics.dist_computations).sum();
        assert_eq!(c.get(), total);
    }

    #[test]
    fn knn_batch_sparse_matches_per_query_bitwise() {
        let ds = synthetic::rna_like(40, 400, 0.1, 35);
        let p = params(2);
        let points: Vec<usize> = (0..4).map(|i| i * 3).collect();
        let mut base1 = Rng::new(36);
        let solo: Vec<KnnResult> = points
            .iter()
            .enumerate()
            .map(|(i, &q)| {
                let mut r = base1.fork(i as u64);
                let mut c = Counter::new();
                knn_point_sparse(&ds, q, Metric::L1, &p, &mut r, &mut c)
            })
            .collect();
        let mut base2 = Rng::new(36);
        let mut c2 = Counter::new();
        let batch =
            knn_batch_sparse(&ds, &points, Metric::L1, &p, &mut base2,
                             &mut c2);
        for (s, b) in solo.iter().zip(&batch) {
            assert_eq!(s.ids, b.ids);
            assert_eq!(s.dists, b.dists);
        }
    }

    #[test]
    fn seeded_batch_matches_solo_under_same_seed() {
        let ds = synthetic::image_like(60, 256, 41);
        let p = params(3);
        let queries: Vec<Vec<f32>> =
            (0..4).map(|i| ds.row_vec(i * 7)).collect();
        let seeds: Vec<u64> = (0..4).map(|i| 0x5EEDu64 * 31 + i).collect();
        let mut c = Counter::new();
        let batch = knn_batch_dense_seeded(
            &ds, &queries, Metric::L2Sq, &p, &mut ScalarEngine, &seeds,
            &mut c, None);
        for ((q, &seed), b) in queries.iter().zip(&seeds).zip(&batch) {
            let mut rng = Rng::new(seed);
            let mut sc = Counter::new();
            let solo = knn_query_dense(&ds, q, Metric::L2Sq, &p,
                                       &mut ScalarEngine, &mut rng,
                                       &mut sc);
            assert_eq!(solo.ids, b.ids);
            assert_eq!(solo.dists, b.dists);
            assert_eq!(solo.metrics.dist_computations,
                       b.metrics.dist_computations);
        }
    }

    #[test]
    fn seeded_batch_is_composition_independent() {
        // the serving determinism the result cache relies on: a query's
        // full answer (ids, dists, unit count) must not depend on which
        // other queries shared its batch
        let ds = synthetic::image_like(60, 256, 43);
        let p = params(3);
        let q0 = ds.row_vec(5);
        let q1 = ds.row_vec(33);
        let mut c1 = Counter::new();
        let alone = knn_batch_dense_seeded(
            &ds, &[q0.clone()], Metric::L2Sq, &p, &mut ScalarEngine,
            &[0xA11CE], &mut c1, None);
        let mut c2 = Counter::new();
        let shared = knn_batch_dense_seeded(
            &ds, &[q1, q0], Metric::L2Sq, &p, &mut ScalarEngine,
            &[0xB0B, 0xA11CE], &mut c2, None);
        assert_eq!(alone[0].ids, shared[1].ids);
        assert_eq!(alone[0].dists, shared[1].dists);
        assert_eq!(alone[0].metrics.dist_computations,
                   shared[1].metrics.dist_computations);
    }

    /// [`ScalarEngine`] that *claims* to be pipelined: the eager default
    /// submit/complete/abandon machinery then drives the speculative
    /// driver paths (predict, confirm-with-gather, discard-and-abandon)
    /// without a network in the loop.
    struct PipelinedScalar;

    impl PullEngine for PipelinedScalar {
        fn partial_sums(
            &mut self,
            data: &DenseDataset,
            query: &[f32],
            rows: &[u32],
            coord_ids: &[u32],
            metric: Metric,
            out_sum: &mut Vec<f64>,
            out_sq: &mut Vec<f64>,
        ) {
            ScalarEngine.partial_sums(data, query, rows, coord_ids, metric,
                                      out_sum, out_sq)
        }

        fn exact_dists(
            &mut self,
            data: &DenseDataset,
            query: &[f32],
            rows: &[u32],
            metric: Metric,
            out: &mut Vec<f64>,
        ) {
            ScalarEngine.exact_dists(data, query, rows, metric, out)
        }

        fn pipelined(&self) -> bool {
            true
        }

        fn name(&self) -> &'static str {
            "pipelined-scalar"
        }
    }

    #[test]
    fn speculation_is_bitwise_invisible_with_live_hit_and_miss_paths() {
        // speculation on vs off must agree bitwise on every answer and
        // every unit count, while genuinely exercising both the
        // confirm (gather-through-permutation) and the discard
        // (abandon-wave) paths across seeds
        let ds = synthetic::image_like(60, 400, 51);
        let p = BanditParams {
            k: 5,
            delta: 0.01,
            policy: PullPolicy {
                init_pulls: 32,
                round_arms: 32,
                round_pulls: 64,
            },
            ..Default::default()
        };
        let mut total = SpecStats::default();
        for seed in 0u64..3 {
            let points: Vec<usize> = (0..3)
                .map(|i| (seed as usize * 11 + i * 7) % 60)
                .collect();
            let mut r1 = Rng::new(seed);
            let mut c1 = Counter::new();
            let (off, s_off) = knn_batch_points_dense_opts(
                &ds, &points, Metric::L2Sq, &p, &mut PipelinedScalar,
                &mut r1, &mut c1, BatchOptions::default());
            assert_eq!(s_off, SpecStats::default(),
                       "speculation-off must count nothing");
            let mut r2 = Rng::new(seed);
            let mut c2 = Counter::new();
            let (on, s_on) = knn_batch_points_dense_opts(
                &ds, &points, Metric::L2Sq, &p, &mut PipelinedScalar,
                &mut r2, &mut c2,
                BatchOptions { speculate: true, ..Default::default() });
            for (a, b) in off.iter().zip(&on) {
                assert_eq!(a.ids, b.ids, "seed {seed}");
                assert_eq!(a.dists, b.dists, "seed {seed}");
                assert_eq!(a.metrics.dist_computations,
                           b.metrics.dist_computations, "seed {seed}");
            }
            assert_eq!(c1.get(), c2.get(), "seed {seed}");
            assert_eq!(s_on.speculated, s_on.confirmed + s_on.discarded,
                       "counter invariant: {s_on:?}");
            total.merge(&s_on);
        }
        assert!(total.speculated > 0, "no speculative pulls submitted");
        assert!(total.confirmed > 0, "prediction never confirmed: {total:?}");
        assert!(total.discarded > 0, "prediction never missed: {total:?}");
    }

    #[test]
    fn speculate_flag_is_inert_on_blocking_engines() {
        // a non-pipelined engine must see the identical call sequence
        // whether or not the flag is set — no speculative waves exist
        let ds = synthetic::image_like(40, 128, 53);
        let queries: Vec<Vec<f32>> =
            (0..3).map(|i| ds.row_vec(i * 9)).collect();
        let p = params(3);
        let mut r1 = Rng::new(54);
        let mut c1 = Counter::new();
        let (off, s_off) = knn_batch_dense_opts(
            &ds, &queries, Metric::L2Sq, &p, &mut ScalarEngine, &mut r1,
            &mut c1, BatchOptions::default());
        let mut r2 = Rng::new(54);
        let mut c2 = Counter::new();
        let (on, s_on) = knn_batch_dense_opts(
            &ds, &queries, Metric::L2Sq, &p, &mut ScalarEngine, &mut r2,
            &mut c2, BatchOptions { speculate: true, ..Default::default() });
        assert_eq!(s_off, SpecStats::default());
        assert_eq!(s_on, SpecStats::default(),
                   "blocking engine must never speculate");
        for (a, b) in off.iter().zip(&on) {
            assert_eq!(a.ids, b.ids);
            assert_eq!(a.dists, b.dists);
        }
        assert_eq!(c1.get(), c2.get());
    }

    #[test]
    fn seeded_speculation_matches_solo_under_same_seed() {
        // the serving path: seeded streams + speculation must still be
        // bitwise-identical to the solo per-query runs the result cache
        // relies on
        let ds = synthetic::image_like(60, 256, 41);
        let p = params(3);
        let queries: Vec<Vec<f32>> =
            (0..4).map(|i| ds.row_vec(i * 7)).collect();
        let seeds: Vec<u64> = (0..4).map(|i| 0x5EEDu64 * 31 + i).collect();
        let mut c = Counter::new();
        let (batch, stats) = knn_batch_dense_seeded_opts(
            &ds, &queries, Metric::L2Sq, &p, &mut PipelinedScalar, &seeds,
            &mut c, BatchOptions { speculate: true, ..Default::default() });
        assert_eq!(stats.speculated, stats.confirmed + stats.discarded);
        for ((q, &seed), b) in queries.iter().zip(&seeds).zip(&batch) {
            let mut rng = Rng::new(seed);
            let mut sc = Counter::new();
            let solo = knn_query_dense(&ds, q, Metric::L2Sq, &p,
                                       &mut ScalarEngine, &mut rng,
                                       &mut sc);
            assert_eq!(solo.ids, b.ids);
            assert_eq!(solo.dists, b.dists);
            assert_eq!(solo.metrics.dist_computations,
                       b.metrics.dist_computations);
        }
    }

    #[test]
    fn dists_are_sorted_and_close_to_truth() {
        let ds = synthetic::image_like(50, 512, 29);
        let mut engine = ScalarEngine;
        let mut rng = Rng::new(30);
        let mut c = Counter::new();
        let res = knn_point_dense(&ds, 0, Metric::L2Sq, &params(5),
                                  &mut engine, &mut rng, &mut c);
        for w in res.dists.windows(2) {
            assert!(w[0] <= w[1] + 1e-6, "dists not sorted: {:?}", res.dists);
        }
        // each reported distance should be near the true distance
        for (&id, &dist) in res.ids.iter().zip(&res.dists) {
            let truth = ds.dist(0, id as usize, Metric::L2Sq,
                                &mut Counter::new());
            assert!((dist - truth).abs() < 0.2 * truth.max(1.0),
                    "dist {dist} vs truth {truth}");
        }
    }
}
