//! L3 coordinator — the paper's system contribution: the BMO UCB bandit
//! state machine, the Monte Carlo boxes, the k-NN / PAC / k-means drivers,
//! and the query server with its HTTP front door and result cache.

pub mod arms;
pub mod bandit;
pub mod cache;
pub mod http;
pub mod kmeans;
pub mod knn;
pub mod pac;
pub mod server;

pub use bandit::{BanditParams, PullPolicy, SigmaMode};
