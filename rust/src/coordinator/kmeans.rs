//! BMO k-means (§V-A, Fig. 5): Lloyd's algorithm with the assignment step
//! solved as n independent 1-NN bandit problems over the k centroids.
//!
//! The assignment step of Lloyd's is exactly "find the nearest neighbor of
//! each point among the k centroids" — a k-armed instance of BMO-NN. Gains
//! come from the d-dimension subsampling (the paper reports 30–50× at
//! k=100 on image data), not from n, so even small k sees large savings
//! when d is big.

use crate::coordinator::arms::PullEngine;
use crate::coordinator::bandit::BanditParams;
use crate::data::dense::{DenseDataset, Metric};
use crate::metrics::{Counter, RunMetrics};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct KMeansParams {
    pub k: usize,
    pub max_iters: usize,
    pub delta: f64,
    /// bandit parameters for each 1-NN assignment subproblem
    pub bandit: BanditParams,
    /// stop when fewer than this fraction of points change assignment
    pub tol_frac: f64,
    /// PAC slack for the assignment bandits as a fraction of the mean
    /// normalized point-centroid distance (paper Fig 5 runs at ">99%
    /// accuracy", not exact assignment; 0.0 = exact). Near-tied centroids
    /// otherwise force exact evaluation and erase the gain.
    pub rel_epsilon: f64,
}

impl Default for KMeansParams {
    fn default() -> Self {
        KMeansParams {
            k: 100,
            max_iters: 20,
            delta: 0.01,
            bandit: BanditParams {
                k: 1,
                // per-assignment bandit: small arm count, so a lighter
                // batch policy than the 32/32/256 default
                policy: crate::coordinator::bandit::PullPolicy {
                    init_pulls: 32,
                    round_arms: 8,
                    round_pulls: 128,
                },
                ..Default::default()
            },
            tol_frac: 0.001,
            rel_epsilon: 0.02,
        }
    }
}

#[derive(Clone, Debug)]
pub struct KMeansResult {
    pub centroids: DenseDataset,
    pub assignment: Vec<usize>,
    pub iters: usize,
    pub metrics: RunMetrics,
    /// per-iteration fraction of points whose bandit assignment matched
    /// the exact nearest centroid (accuracy metric of Appendix D-C2)
    pub assign_accuracy: Vec<f64>,
}

/// k-means++-style seeding (distance-proportional), counted.
fn seed_centroids(data: &DenseDataset, k: usize, metric: Metric,
                  rng: &mut Rng, counter: &mut Counter) -> DenseDataset {
    let mut centroids = DenseDataset::zeros(k, data.d);
    let first = rng.below(data.n);
    centroids.row_mut(0).copy_from_slice(data.row(first));
    let mut d2: Vec<f64> = (0..data.n)
        .map(|i| data.dist_to(i, centroids.row(0), metric, counter))
        .collect();
    for c in 1..k {
        let total: f64 = d2.iter().sum();
        let mut target = rng.f64() * total;
        let mut pick = data.n - 1;
        for (i, &w) in d2.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                pick = i;
                break;
            }
        }
        centroids.row_mut(c).copy_from_slice(data.row(pick));
        for i in 0..data.n {
            let nd = data.dist_to(i, centroids.row(c), metric, counter);
            if nd < d2[i] {
                d2[i] = nd;
            }
        }
    }
    centroids
}

/// Exact assignment step (used for the baseline and accuracy checks).
pub fn assign_exact(data: &DenseDataset, centroids: &DenseDataset,
                    metric: Metric, counter: &mut Counter) -> Vec<usize> {
    (0..data.n)
        .map(|i| {
            let mut best = 0usize;
            let mut best_d = f64::INFINITY;
            for c in 0..centroids.n {
                let d = data.dist_to(i, centroids.row(c), metric, counter);
                if d < best_d {
                    best_d = d;
                    best = c;
                }
            }
            best
        })
        .collect()
}

/// Bandit assignment step: each point runs a 1-NN bandit over the
/// centroid set — executed through the coalesced multi-query driver
/// (`coordinator::knn::knn_batch_dense` with the centroid set as the
/// dataset), so every lockstep round resolves all points' staged pulls
/// in one `PullEngine::pull_batch` sweep of the centroid block. The
/// centroid rows are shared across every point, which is the best-case
/// workload for the row-major sweep: each centroid block is read once
/// per round instead of once per point. Point `i` runs on the rng
/// stream `rng.fork(i)` and its assignment is bitwise-identical to the
/// per-point path (one `run_bmo_ucb` per point under the same fork) —
/// pinned by `batched_assignment_is_bitwise_identical_to_per_point`.
pub fn assign_bandit<E: PullEngine>(
    data: &DenseDataset,
    centroids: &DenseDataset,
    metric: Metric,
    bandit: &BanditParams,
    engine: &mut E,
    rng: &mut Rng,
    counter: &mut Counter,
) -> Vec<usize> {
    // queries are the dataset's own rows — borrow, don't copy
    let queries: Vec<&[f32]> = (0..data.n).map(|i| data.row(i)).collect();
    let results = crate::coordinator::knn::knn_batch_dense(
        centroids, &queries, metric, bandit, engine, rng, counter);
    results.iter().map(|r| r.ids[0] as usize).collect()
}

/// Full BMO k-means: Lloyd iterations with bandit assignment.
pub fn kmeans_bmo<E: PullEngine>(
    data: &DenseDataset,
    params: &KMeansParams,
    engine: &mut E,
    rng: &mut Rng,
) -> KMeansResult {
    let metric = Metric::L2Sq;
    let mut counter = Counter::new();
    let t0 = std::time::Instant::now();
    let mut centroids =
        seed_centroids(data, params.k, metric, rng, &mut counter);
    let mut assignment = vec![usize::MAX; data.n];
    let mut accuracy = Vec::new();
    let mut iters = 0;
    let mut per_assign = params.bandit.clone();
    per_assign.delta = params.delta / (data.n * params.max_iters) as f64;
    if per_assign.epsilon == 0.0 && params.rel_epsilon > 0.0 {
        // auto-scale the PAC slack from the data: mean normalized distance
        // of points to a sample of centroids
        let mut acc = 0f64;
        let mut cnt = 0u64;
        let sample = 64.min(data.n);
        for i in 0..sample {
            let c = i % params.k;
            acc += data.dist_to(i, centroids.row(c), metric,
                                &mut Counter::new());
            cnt += 1;
        }
        let mean_theta = acc / cnt as f64 / data.d as f64;
        per_assign.epsilon = params.rel_epsilon * mean_theta;
    }
    for it in 0..params.max_iters {
        iters = it + 1;
        let new_assign = assign_bandit(data, &centroids, metric,
                                       &per_assign, engine, rng,
                                       &mut counter);
        // accuracy vs exact assignment (not charged to the BMO counter)
        let exact = assign_exact(data, &centroids, metric,
                                 &mut Counter::new());
        let agree = new_assign
            .iter()
            .zip(&exact)
            .filter(|(a, b)| a == b)
            .count();
        accuracy.push(agree as f64 / data.n as f64);

        let changed = new_assign
            .iter()
            .zip(&assignment)
            .filter(|(a, b)| a != b)
            .count();
        assignment = new_assign;
        // update step: O(nd), not counted as distance computations
        centroids = update_centroids(data, &assignment, params.k, &centroids);
        if changed as f64 <= params.tol_frac * data.n as f64 {
            break;
        }
    }
    KMeansResult {
        centroids,
        assignment,
        iters,
        metrics: RunMetrics {
            dist_computations: counter.get(),
            rounds: iters as u64,
            exact_evals: 0,
            elapsed: t0.elapsed(),
        },
        assign_accuracy: accuracy,
    }
}

/// Exact Lloyd's (the Fig-5 baseline); same seeding, exact assignment.
pub fn kmeans_exact(data: &DenseDataset, params: &KMeansParams,
                    rng: &mut Rng) -> KMeansResult {
    let metric = Metric::L2Sq;
    let mut counter = Counter::new();
    let t0 = std::time::Instant::now();
    let mut centroids =
        seed_centroids(data, params.k, metric, rng, &mut counter);
    let mut assignment = vec![usize::MAX; data.n];
    let mut iters = 0;
    for it in 0..params.max_iters {
        iters = it + 1;
        let new_assign = assign_exact(data, &centroids, metric, &mut counter);
        let changed = new_assign
            .iter()
            .zip(&assignment)
            .filter(|(a, b)| a != b)
            .count();
        assignment = new_assign;
        centroids = update_centroids(data, &assignment, params.k, &centroids);
        if changed as f64 <= params.tol_frac * data.n as f64 {
            break;
        }
    }
    KMeansResult {
        centroids,
        assignment,
        iters,
        metrics: RunMetrics {
            dist_computations: counter.get(),
            rounds: iters as u64,
            exact_evals: 0,
            elapsed: t0.elapsed(),
        },
        assign_accuracy: vec![1.0; iters],
    }
}

fn update_centroids(data: &DenseDataset, assignment: &[usize], k: usize,
                    old: &DenseDataset) -> DenseDataset {
    let mut sums = vec![0f64; k * data.d];
    let mut counts = vec![0usize; k];
    for (i, &a) in assignment.iter().enumerate() {
        counts[a] += 1;
        let row = data.row(i);
        let s = &mut sums[a * data.d..(a + 1) * data.d];
        for (acc, &v) in s.iter_mut().zip(row) {
            *acc += v as f64;
        }
    }
    let mut out = DenseDataset::zeros(k, data.d);
    for c in 0..k {
        let row = out.row_mut(c);
        if counts[c] == 0 {
            // empty cluster: keep old centroid
            row.copy_from_slice(old.row(c));
        } else {
            let s = &sums[c * data.d..(c + 1) * data.d];
            for (v, &acc) in row.iter_mut().zip(s) {
                *v = (acc / counts[c] as f64) as f32;
            }
        }
    }
    out
}

/// Within-cluster sum of squares (quality metric for comparisons).
pub fn wcss(data: &DenseDataset, centroids: &DenseDataset,
            assignment: &[usize]) -> f64 {
    let mut c = Counter::new();
    assignment
        .iter()
        .enumerate()
        .map(|(i, &a)| data.dist_to(i, centroids.row(a), Metric::L2Sq, &mut c))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::arms::{ArmSet, DenseArms, ScalarEngine};
    use crate::coordinator::bandit::run_bmo_ucb;
    use crate::data::synthetic;

    fn small_params(k: usize) -> KMeansParams {
        KMeansParams { k, max_iters: 8, ..Default::default() }
    }

    /// The pre-refactor per-point assignment loop, kept as the
    /// reference the batched path must match bitwise.
    fn assign_per_point<E: PullEngine>(
        data: &DenseDataset,
        centroids: &DenseDataset,
        metric: Metric,
        bandit: &BanditParams,
        engine: &mut E,
        rng: &mut Rng,
        counter: &mut Counter,
    ) -> Vec<usize> {
        let rows: Vec<u32> = (0..centroids.n as u32).collect();
        (0..data.n)
            .map(|i| {
                let mut qrng = rng.fork(i as u64);
                let query = data.row_vec(i);
                let mut arms = DenseArms::new(centroids, &query, &rows,
                                              metric, engine);
                let res = run_bmo_ucb(&mut arms, bandit.clone(),
                                      &mut qrng, counter);
                arms.arm_id(res.best[0].0) as usize
            })
            .collect()
    }

    #[test]
    fn batched_assignment_is_bitwise_identical_to_per_point() {
        // the coalesced assignment sweep must agree with the per-point
        // bandit loop exactly — same assignments, same unit accounting
        // — for both engines, because the batch driver forks the same
        // per-point rng streams
        let (ds, _) = synthetic::clustered(120, 96, 5, 0.3, 61);
        let mut seed_rng = Rng::new(62);
        let centroids = seed_centroids(&ds, 5, Metric::L2Sq, &mut seed_rng,
                                       &mut Counter::new());
        let bandit = small_params(5).bandit;
        let mut e1 = ScalarEngine;
        let mut rng1 = Rng::new(63);
        let mut c1 = Counter::new();
        let batched = assign_bandit(&ds, &centroids, Metric::L2Sq,
                                    &bandit, &mut e1, &mut rng1, &mut c1);
        let mut e2 = ScalarEngine;
        let mut rng2 = Rng::new(63);
        let mut c2 = Counter::new();
        let per_point = assign_per_point(&ds, &centroids, Metric::L2Sq,
                                         &bandit, &mut e2, &mut rng2,
                                         &mut c2);
        assert_eq!(batched, per_point);
        assert_eq!(c1.get(), c2.get(), "unit accounting diverged");
        // and the rng streams stayed in lockstep
        assert_eq!(rng1.next_u64(), rng2.next_u64());
    }

    #[test]
    fn bandit_assignment_matches_exact_mostly() {
        let (ds, _) = synthetic::clustered(200, 256, 5, 0.3, 41);
        let mut engine = ScalarEngine;
        let mut rng = Rng::new(42);
        let mut c = Counter::new();
        let centroids = seed_centroids(&ds, 5, Metric::L2Sq, &mut rng,
                                       &mut Counter::new());
        let bandit = small_params(5).bandit;
        let got = assign_bandit(&ds, &centroids, Metric::L2Sq, &bandit,
                                &mut engine, &mut rng, &mut c);
        let want = assign_exact(&ds, &centroids, Metric::L2Sq,
                                &mut Counter::new());
        let agree =
            got.iter().zip(&want).filter(|(a, b)| a == b).count();
        assert!(agree as f64 >= 0.97 * ds.n as f64,
                "agreement {agree}/{}", ds.n);
    }

    #[test]
    fn kmeans_bmo_recovers_clusters() {
        let (ds, labels) = synthetic::clustered(300, 128, 4, 0.2, 43);
        let mut engine = ScalarEngine;
        let mut rng = Rng::new(44);
        let res = kmeans_bmo(&ds, &small_params(4), &mut engine, &mut rng);
        // cluster purity: majority label per cluster should dominate
        let mut purity = 0usize;
        for c in 0..4 {
            let mut counts = std::collections::HashMap::new();
            for (i, &a) in res.assignment.iter().enumerate() {
                if a == c {
                    *counts.entry(labels[i]).or_insert(0usize) += 1;
                }
            }
            purity += counts.values().copied().max().unwrap_or(0);
        }
        assert!(purity as f64 >= 0.9 * ds.n as f64,
                "purity {purity}/{}", ds.n);
    }

    #[test]
    fn bmo_uses_fewer_distance_computations_than_exact() {
        let (ds, _) = synthetic::clustered(150, 2048, 8, 0.3, 45);
        let mut engine = ScalarEngine;
        let mut rng1 = Rng::new(46);
        let bmo = kmeans_bmo(&ds, &small_params(8), &mut engine, &mut rng1);
        let mut rng2 = Rng::new(46);
        let exact = kmeans_exact(&ds, &small_params(8), &mut rng2);
        // normalize per iteration (they may run different iteration counts)
        let bmo_per = bmo.metrics.dist_computations / bmo.iters as u64;
        let exact_per = exact.metrics.dist_computations / exact.iters as u64;
        assert!(bmo_per * 2 < exact_per,
                "bmo {bmo_per}/iter vs exact {exact_per}/iter");
        // and the accuracy stayed high
        let last_acc = *bmo.assign_accuracy.last().unwrap();
        assert!(last_acc > 0.95, "accuracy {last_acc}");
    }

    #[test]
    fn empty_cluster_keeps_old_centroid() {
        let ds = synthetic::gaussian_iid(10, 4, 47);
        let assignment = vec![0usize; 10]; // all in cluster 0; cluster 1 empty
        let old = synthetic::gaussian_iid(2, 4, 48);
        let updated = update_centroids(&ds, &assignment, 2, &old);
        assert_eq!(updated.row(1), old.row(1));
    }

    #[test]
    fn wcss_decreases_with_iterations() {
        let (ds, _) = synthetic::clustered(200, 64, 4, 0.5, 49);
        let mut rng = Rng::new(50);
        let res = kmeans_exact(&ds, &small_params(4), &mut rng);
        let final_wcss = wcss(&ds, &res.centroids, &res.assignment);
        // compare to a random assignment's wcss
        let mut rng2 = Rng::new(51);
        let rand_assign: Vec<usize> =
            (0..ds.n).map(|_| rng2.below(4)).collect();
        let rand_wcss = wcss(&ds, &res.centroids, &rand_assign);
        assert!(final_wcss < rand_wcss * 0.5,
                "wcss {final_wcss} vs random {rand_wcss}");
    }
}
