//! PAC BMO-NN (Theorem 2): additive-ε approximate nearest neighbors.
//!
//! The only change from exact BMO-NN is the modified emission rule of
//! BMO UCB line 7 — an arm is also emitted when its confidence half-width
//! drops below ε/2 — which is already wired through
//! `BanditParams::epsilon`. This module provides the user-facing API and
//! the Corollary-1 instrumentation (power-law gap regimes).

use crate::coordinator::arms::PullEngine;
use crate::coordinator::bandit::BanditParams;
use crate::coordinator::knn::{knn_point_dense, KnnResult};
use crate::data::dense::{DenseDataset, Metric};
use crate::metrics::Counter;
use crate::util::rng::Rng;

/// PAC k-NN of an in-dataset point: returns, w.p. ≥ 1−δ, k points whose
/// θ is within ε of the true k-th nearest neighbor's θ.
///
/// NOTE ε is in *normalized* θ-units (θ = ρ/d), matching the paper's
/// arm-mean formulation.
pub fn pac_knn_point_dense<E: PullEngine>(
    data: &DenseDataset,
    q: usize,
    metric: Metric,
    epsilon: f64,
    params: &BanditParams,
    engine: &mut E,
    rng: &mut Rng,
    counter: &mut Counter,
) -> KnnResult {
    assert!(epsilon > 0.0, "use knn_point_dense for exact identification");
    let p = BanditParams { epsilon, ..params.clone() };
    knn_point_dense(data, q, metric, &p, engine, rng, counter)
}

/// Check a PAC answer: every returned point's θ must be ≤ θ_(k) + ε.
///
/// Panics when `k` is 0 or exceeds the number of candidate rows
/// (`data.n - 1` — the query itself is not its own neighbor): θ_(k)
/// does not exist for such a `k`, so the check would be meaningless.
pub fn is_eps_correct(
    data: &DenseDataset,
    q: usize,
    metric: Metric,
    result: &KnnResult,
    k: usize,
    epsilon: f64,
) -> bool {
    let candidates = data.n - (q < data.n) as usize;
    assert!(k >= 1 && k <= candidates,
            "is_eps_correct: k = {k} but the dataset has {candidates} \
             candidate rows (n = {} minus the query itself) — θ_(k) \
             is undefined",
            data.n);
    let mut c = Counter::new();
    let d = data.d as f64;
    let mut thetas: Vec<f64> = (0..data.n)
        .filter(|&i| i != q)
        .map(|i| data.dist(q, i, metric, &mut c) / d)
        .collect();
    thetas.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let theta_k = thetas[k - 1];
    result.ids.iter().all(|&id| {
        let th = data.dist(q, id as usize, metric, &mut c) / d;
        th <= theta_k + epsilon + 1e-9
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::arms::ScalarEngine;
    use crate::coordinator::bandit::PullPolicy;
    use crate::data::synthetic;

    fn base_params(k: usize) -> BanditParams {
        BanditParams { k, delta: 0.01, policy: PullPolicy::batched(),
                       ..Default::default() }
    }

    #[test]
    fn pac_answer_is_eps_correct() {
        // power-law gaps with small alpha: many arms near the best — the
        // regime where PAC mode pays off (Corollary 1)
        let ds = synthetic::power_law_gaps(150, 1024, 0.5, 1.0, 31);
        let mut engine = ScalarEngine;
        let mut rng = Rng::new(32);
        let mut c = Counter::new();
        let eps = 0.3;
        let res = pac_knn_point_dense(&ds, 0, Metric::L2Sq, eps,
                                      &base_params(1), &mut engine,
                                      &mut rng, &mut c);
        assert!(is_eps_correct(&ds, 0, Metric::L2Sq, &res, 1, eps));
    }

    #[test]
    fn pac_is_cheaper_than_exact_on_hard_instances() {
        // clustered gaps: exact must separate near-ties; PAC may stop early
        let ds = synthetic::power_law_gaps(200, 2048, 0.3, 1.0, 33);
        let mut engine = ScalarEngine;

        let mut rng = Rng::new(34);
        let mut c_exact = Counter::new();
        let _ = knn_point_dense(&ds, 0, Metric::L2Sq, &base_params(1),
                                &mut engine, &mut rng, &mut c_exact);

        let mut rng = Rng::new(34);
        let mut c_pac = Counter::new();
        let res = pac_knn_point_dense(&ds, 0, Metric::L2Sq, 0.5,
                                      &base_params(1), &mut engine,
                                      &mut rng, &mut c_pac);
        assert!(is_eps_correct(&ds, 0, Metric::L2Sq, &res, 1, 0.5));
        assert!(
            c_pac.get() <= c_exact.get(),
            "PAC {} should not exceed exact {}",
            c_pac.get(),
            c_exact.get()
        );
    }

    #[test]
    #[should_panic(expected = "θ_(k) is undefined")]
    fn oversized_k_is_rejected_not_an_index_panic() {
        // regression: k > n-1 used to reach `thetas[k - 1]` and die with
        // a bare slice-index panic; now it must fail the up-front
        // validation with a message that names the actual limit
        let ds = synthetic::gaussian_iid(6, 16, 37);
        let res = KnnResult { ids: vec![1], dists: vec![0.0],
                              metrics: Default::default(),
                              coverage: None };
        let _ = is_eps_correct(&ds, 0, Metric::L2Sq, &res, 6, 0.1);
    }

    #[test]
    #[should_panic(expected = "θ_(k) is undefined")]
    fn zero_k_is_rejected() {
        let ds = synthetic::gaussian_iid(6, 16, 38);
        let res = KnnResult { ids: vec![1], dists: vec![0.0],
                              metrics: Default::default(),
                              coverage: None };
        let _ = is_eps_correct(&ds, 0, Metric::L2Sq, &res, 0, 0.1);
    }

    #[test]
    fn boundary_k_equal_to_candidate_count_is_accepted() {
        // k = n - 1 is the largest well-defined order statistic; the
        // check must run (and trivially pass when every point is
        // returned) rather than trip the validation
        let ds = synthetic::gaussian_iid(6, 16, 39);
        let res = KnnResult { ids: vec![1, 2, 3, 4, 5],
                              dists: vec![0.0; 5],
                              metrics: Default::default(),
                              coverage: None };
        assert!(is_eps_correct(&ds, 0, Metric::L2Sq, &res, 5, 0.1));
    }

    #[test]
    #[should_panic(expected = "exact identification")]
    fn zero_epsilon_rejected() {
        let ds = synthetic::gaussian_iid(10, 32, 35);
        let mut engine = ScalarEngine;
        let mut rng = Rng::new(36);
        let mut c = Counter::new();
        let _ = pac_knn_point_dense(&ds, 0, Metric::L2Sq, 0.0,
                                    &base_params(1), &mut engine, &mut rng,
                                    &mut c);
    }
}
