//! Fingerprint-keyed LRU result cache for the serving front door.
//!
//! The cache sits *in front of* the worker pool: a hit returns the
//! stored response without consuming a queue slot, a worker, or a
//! single bandit pull. Correctness rests on two pillars:
//!
//! * **Keying** ([`CacheKey`]): an entry matches only for the same
//!   query content (FNV-1a over the f32 bit patterns via
//!   [`hash_query`], with the full bits double-checked on hit so a
//!   hash collision can never serve a wrong answer), the same `k`, the
//!   same accuracy mode (`epsilon`/`delta` bit patterns), the same
//!   dataset fingerprint (`wire::dataset_fingerprint`, PR 5), and the
//!   same placement epoch. Reloading data or bumping the epoch changes
//!   the key, so stale entries are never *matched* again — they simply
//!   age out of the LRU. Invalidation is free.
//! * **Byte identity**: the server computes every query under a
//!   content-derived rng seed
//!   ([`crate::coordinator::knn::knn_batch_dense_seeded`]), so the
//!   answer a hit replays is bitwise-identical to what a fresh compute
//!   would produce. The cache can therefore never be observed — except
//!   in the latency column and the `cache_hits` counter.
//!
//! Only full-coverage successes are inserted: degraded
//! (coverage-annotated), deadline-exceeded, shed and error answers
//! must always be recomputed (the server enforces this gate).

use std::collections::{BTreeMap, HashMap};

use crate::util::json::Json;

/// FNV-1a offset basis (64-bit), matching `wire::dataset_fingerprint`.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a prime (64-bit).
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over `k` and the query's f32 bit patterns.
///
/// Doubles as the serving rng seed: the same function keys the cache
/// *and* seeds `knn_batch_dense_seeded`, so "same key" and "same
/// compute stream" are one property, not two that must be kept in sync.
pub fn hash_query(query: &[f32], k: usize) -> u64 {
    let mut h = FNV_OFFSET;
    let mut eat = |b: u8| {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    };
    for b in (k as u64).to_le_bytes() {
        eat(b);
    }
    for v in query {
        for b in v.to_bits().to_le_bytes() {
            eat(b);
        }
    }
    h
}

/// The full identity of a cached answer. Every field that can change
/// the bytes of a correct response is part of the key.
#[derive(Clone, Debug, Hash, PartialEq, Eq)]
pub struct CacheKey {
    /// [`hash_query`] over the query bits and `k`.
    pub query_hash: u64,
    /// Number of neighbors requested.
    pub k: usize,
    /// Bit pattern of the server's `epsilon` (accuracy mode).
    pub eps_bits: u64,
    /// Bit pattern of the server's `delta` (failure probability).
    pub delta_bits: u64,
    /// `wire::dataset_fingerprint` of the served dataset.
    pub fingerprint: u64,
    /// Placement epoch at lookup time; bumping it orphans every older
    /// entry.
    pub epoch: u64,
}

struct Entry {
    /// Full query bit patterns — compared on hit so an FNV collision
    /// degrades to a miss, never to a wrong answer.
    query: Vec<u32>,
    resp: Json,
    stamp: u64,
}

/// A strict-LRU map from [`CacheKey`] to a stored response, with hit /
/// miss / insertion / eviction counters surfaced in server stats.
pub struct ResultCache {
    cap: usize,
    map: HashMap<CacheKey, Entry>,
    /// Recency index: stamp → key, oldest stamp first.
    lru: BTreeMap<u64, CacheKey>,
    clock: u64,
    hits: u64,
    misses: u64,
    insertions: u64,
    evictions: u64,
}

impl ResultCache {
    /// A cache holding at most `cap` entries (`cap` floors at 1 — a
    /// disabled cache is represented by *not constructing one*).
    pub fn new(cap: usize) -> Self {
        ResultCache {
            cap: cap.max(1),
            map: HashMap::new(),
            lru: BTreeMap::new(),
            clock: 0,
            hits: 0,
            misses: 0,
            insertions: 0,
            evictions: 0,
        }
    }

    /// Look up `key`, verifying the stored query bits against `query`.
    /// A hit bumps the entry's recency and returns a clone of the
    /// stored response; anything else counts a miss.
    pub fn get(&mut self, key: &CacheKey, query: &[f32]) -> Option<Json> {
        match self.map.get_mut(key) {
            Some(e) if bits_equal(&e.query, query) => {
                self.lru.remove(&e.stamp);
                self.clock += 1;
                e.stamp = self.clock;
                self.lru.insert(e.stamp, key.clone());
                self.hits += 1;
                Some(e.resp.clone())
            }
            _ => {
                self.misses += 1;
                None
            }
        }
    }

    /// Store `resp` under `key`, evicting the least-recently-used entry
    /// if the cache is at capacity.
    pub fn insert(&mut self, key: CacheKey, query: &[f32], resp: Json) {
        self.clock += 1;
        let stamp = self.clock;
        let entry = Entry {
            query: query.iter().map(|v| v.to_bits()).collect(),
            resp,
            stamp,
        };
        if let Some(old) = self.map.insert(key.clone(), entry) {
            // overwrite: drop the superseded recency slot
            self.lru.remove(&old.stamp);
        } else if self.map.len() > self.cap {
            if let Some((&oldest, _)) = self.lru.iter().next() {
                if let Some(victim) = self.lru.remove(&oldest) {
                    self.map.remove(&victim);
                    self.evictions += 1;
                }
            }
        }
        self.lru.insert(stamp, key);
        self.insertions += 1;
    }

    /// Entries currently stored.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when no entries are stored.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Configured capacity in entries.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Lookups answered from the cache since startup.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Lookups that fell through to compute since startup.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Entries stored since startup (including overwrites).
    pub fn insertions(&self) -> u64 {
        self.insertions
    }

    /// Entries displaced by capacity pressure since startup.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }
}

fn bits_equal(stored: &[u32], query: &[f32]) -> bool {
    stored.len() == query.len()
        && stored.iter().zip(query).all(|(&b, v)| b == v.to_bits())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(tag: u64) -> CacheKey {
        CacheKey { query_hash: tag, k: 3, eps_bits: 0, delta_bits: 0,
                   fingerprint: 7, epoch: 0 }
    }

    fn resp(tag: u64) -> Json {
        Json::obj(vec![("ok", Json::Bool(true)),
                       ("tag", Json::Num(tag as f64))])
    }

    #[test]
    fn hit_replays_the_stored_response_bytes() {
        let mut c = ResultCache::new(4);
        let q = [1.0f32, 2.0];
        c.insert(key(1), &q, resp(1));
        let got = c.get(&key(1), &q).expect("hit");
        assert_eq!(got.to_string(), resp(1).to_string());
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 0);
    }

    #[test]
    fn capacity_evicts_least_recently_used() {
        let mut c = ResultCache::new(2);
        let q = [0.5f32];
        c.insert(key(1), &q, resp(1));
        c.insert(key(2), &q, resp(2));
        // touch 1 so 2 becomes the LRU victim
        assert!(c.get(&key(1), &q).is_some());
        c.insert(key(3), &q, resp(3));
        assert_eq!(c.len(), 2);
        assert_eq!(c.evictions(), 1);
        assert!(c.get(&key(2), &q).is_none(), "LRU entry must be gone");
        assert!(c.get(&key(1), &q).is_some());
        assert!(c.get(&key(3), &q).is_some());
    }

    #[test]
    fn hash_collision_degrades_to_a_miss() {
        let mut c = ResultCache::new(4);
        c.insert(key(9), &[1.0f32], resp(9));
        // same key fields, different query bits: must not serve
        assert!(c.get(&key(9), &[2.0f32]).is_none());
        assert_eq!(c.misses(), 1);
    }

    #[test]
    fn epoch_is_part_of_the_key() {
        let mut c = ResultCache::new(4);
        let q = [3.0f32];
        c.insert(key(5), &q, resp(5));
        let mut bumped = key(5);
        bumped.epoch = 1;
        assert!(c.get(&bumped, &q).is_none());
        assert!(c.get(&key(5), &q).is_some());
    }

    #[test]
    fn overwrite_does_not_leak_recency_slots() {
        let mut c = ResultCache::new(2);
        let q = [1.0f32];
        c.insert(key(1), &q, resp(1));
        c.insert(key(1), &q, resp(2));
        assert_eq!(c.len(), 1);
        assert_eq!(c.insertions(), 2);
        let got = c.get(&key(1), &q).unwrap();
        assert_eq!(got.to_string(), resp(2).to_string());
    }

    #[test]
    fn query_hash_depends_on_content_and_k() {
        let a = hash_query(&[1.0, 2.0], 3);
        assert_eq!(a, hash_query(&[1.0, 2.0], 3));
        assert_ne!(a, hash_query(&[1.0, 2.0], 4));
        assert_ne!(a, hash_query(&[1.0, 2.5], 3));
        // -0.0 and 0.0 differ bitwise → distinct streams and entries
        assert_ne!(hash_query(&[0.0], 1), hash_query(&[-0.0], 1));
    }
}
