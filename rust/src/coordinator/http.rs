//! HTTP/1.1 front door for the query server — hand-rolled on `std`,
//! matching the repo's zero-dependency stance.
//!
//! The HTTP listener is a second transport over the *same* serving
//! path as the line protocol in [`crate::coordinator::server`]: `POST
//! /knn` routes through the identical validation, deadline stamping,
//! result cache and `max_queue` admission (`server::handle_knn`), and
//! `GET /metrics` returns the identical `stats` body. What HTTP adds
//! is a real status-code contract:
//!
//! | response | status |
//! |---|---|
//! | `ok: true` (including degraded/coverage answers) | `200` |
//! | validation / parse errors | `400` |
//! | `kind: "overload"` (queue full) | `429` + `Retry-After` |
//! | `kind: "deadline_exceeded"` | `504` |
//! | internal error / engine unavailable / shutting down | `500` |
//! | unknown path | `404` |
//! | wrong method on a known path | `405` + `Allow` |
//! | oversized head or body | `431` / `413` |
//!
//! Endpoints: `POST /knn` (same JSON body as the `knn` op, minus the
//! `op` field — it is implied by the path), `GET /metrics`, `GET
//! /healthz`, `POST /admin/epoch-bump`, `POST /admin/reshard` (same
//! body as the `reshard` op: `{"to":[spec,...], "epoch":e?}` — see
//! [`crate::coordinator::server`]). Bodies are JSON either way;
//! `429` responses carry `Retry-After` in whole seconds (rounded up
//! from the body's `retry_after_ms`, minimum 1). Connections are
//! keep-alive by default (HTTP/1.1 semantics; `Connection: close`
//! honored). Every route's request wall-clock lands in its own
//! per-route latency window, surfaced as the `routes` object of
//! `GET /metrics`.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::server::{epoch_bump_json, handle_knn,
                                 record_route, reshard_json, stats_json,
                                 Shared};
use crate::runtime::placement::RetryPolicy;
use crate::util::json::Json;

/// Refuse request heads (request line + headers) larger than this.
const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Refuse request bodies larger than this (a 64k-dim f32 query in JSON
/// text fits comfortably).
const MAX_BODY_BYTES: usize = 8 * 1024 * 1024;

/// Accept loop for the HTTP listener; structured like the line
/// protocol's accept loop (nonblocking listener, decaying idle poll,
/// one I/O thread per connection, joined on shutdown).
pub(crate) fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    let mut handles = Vec::new();
    let idle = RetryPolicy {
        backoff_base: Duration::from_millis(5),
        backoff_max: Duration::from_millis(50),
    };
    let mut idle_polls = 0u32;
    while !shared.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                idle_polls = 0;
                let s = shared.clone();
                handles.push(std::thread::spawn(move || {
                    let _ = handle_http_conn(stream, s);
                }));
                handles.retain(|h| !h.is_finished());
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                idle_polls = idle_polls.saturating_add(1);
                std::thread::sleep(idle.backoff(idle_polls));
            }
            Err(_) => break,
        }
    }
    for h in handles {
        let _ = h.join();
    }
}

/// One parsed request.
struct Request {
    method: String,
    path: String,
    body: Vec<u8>,
    close: bool,
}

/// What request reading produced: a request, a client hangup, or a
/// protocol-level refusal that still gets an HTTP answer.
enum ReadOutcome {
    Request(Request),
    Closed,
    /// (status, reason, message) — answered, then the connection closes
    Refuse(u16, &'static str, String),
}

fn handle_http_conn(stream: TcpStream, shared: Arc<Shared>)
                    -> std::io::Result<()> {
    // short read timeout so connection threads notice shutdown instead
    // of blocking forever while stop() joins them
    stream.set_read_timeout(Some(Duration::from_millis(100)))?;
    stream.set_nodelay(true)?;
    let mut writer = stream.try_clone()?;
    let mut reader = stream;
    let mut acc: Vec<u8> = Vec::new();
    loop {
        match read_request(&mut reader, &mut acc, &shared)? {
            ReadOutcome::Closed => return Ok(()),
            ReadOutcome::Refuse(status, reason, msg) => {
                let body = Json::obj(vec![
                    ("ok", Json::Bool(false)),
                    ("error", Json::Str(msg)),
                ]);
                write_response(&mut writer, status, reason,
                               &body.to_string(), &[], true)?;
                return Ok(());
            }
            ReadOutcome::Request(req) => {
                let close = req.close;
                route(&mut writer, &req, &shared, close)?;
                if close || shared.shutdown.load(Ordering::SeqCst) {
                    return Ok(());
                }
            }
        }
    }
}

/// Read one request from the connection. `acc` carries bytes across
/// calls (pipelined requests, partial reads).
fn read_request(reader: &mut TcpStream, acc: &mut Vec<u8>,
                shared: &Shared) -> std::io::Result<ReadOutcome> {
    let mut chunk = [0u8; 4096];
    // phase 1: accumulate the head (request line + headers)
    let head_end = loop {
        if let Some(pos) = find_head_end(acc) {
            break pos;
        }
        if acc.len() > MAX_HEAD_BYTES {
            return Ok(ReadOutcome::Refuse(
                431, "Request Header Fields Too Large",
                "request head too large".into()));
        }
        match reader.read(&mut chunk) {
            Ok(0) => return Ok(ReadOutcome::Closed),
            Ok(n) => acc.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return Ok(ReadOutcome::Closed);
                }
            }
            Err(e) => return Err(e),
        }
    };
    let head: Vec<u8> = acc.drain(..head_end.total).collect();
    let head = String::from_utf8_lossy(&head[..head_end.head_len])
        .into_owned();
    let mut lines = head.split("\r\n").flat_map(|l| l.split('\n'));
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let target = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if method.is_empty() || target.is_empty()
        || !version.starts_with("HTTP/1.")
    {
        return Ok(ReadOutcome::Refuse(400, "Bad Request",
                                      "malformed request line".into()));
    }
    let mut content_length = 0usize;
    // HTTP/1.0 closes by default, 1.1 keeps alive
    let mut close = version == "HTTP/1.0";
    for line in lines {
        let Some((name, value)) = line.split_once(':') else { continue };
        let name = name.trim().to_ascii_lowercase();
        let value = value.trim();
        match name.as_str() {
            "content-length" => {
                let Ok(n) = value.parse::<usize>() else {
                    return Ok(ReadOutcome::Refuse(
                        400, "Bad Request",
                        "bad content-length".into()));
                };
                content_length = n;
            }
            "connection" => {
                let v = value.to_ascii_lowercase();
                if v.contains("close") {
                    close = true;
                } else if v.contains("keep-alive") {
                    close = false;
                }
            }
            _ => {}
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Ok(ReadOutcome::Refuse(413, "Content Too Large",
                                      "request body too large".into()));
    }
    // phase 2: accumulate the body
    while acc.len() < content_length {
        match reader.read(&mut chunk) {
            Ok(0) => return Ok(ReadOutcome::Closed),
            Ok(n) => acc.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return Ok(ReadOutcome::Closed);
                }
            }
            Err(e) => return Err(e),
        }
    }
    let body: Vec<u8> = acc.drain(..content_length).collect();
    // ignore any query string: routing is on the path alone
    let path = target.split('?').next().unwrap_or("").to_string();
    Ok(ReadOutcome::Request(Request { method, path, body, close }))
}

/// Where the head terminator was found: `head_len` bytes of head,
/// `total` bytes to drain (head + terminator).
struct HeadEnd {
    head_len: usize,
    total: usize,
}

fn find_head_end(acc: &[u8]) -> Option<HeadEnd> {
    // standard CRLFCRLF, with bare LFLF tolerated for hand-rolled
    // clients
    if let Some(pos) = acc.windows(4).position(|w| w == b"\r\n\r\n") {
        return Some(HeadEnd { head_len: pos, total: pos + 4 });
    }
    acc.windows(2)
        .position(|w| w == b"\n\n")
        .map(|pos| HeadEnd { head_len: pos, total: pos + 2 })
}

/// Dispatch one request and write its response, recording the
/// request's wall-clock (parse-to-write) under its route label in the
/// server's per-route latency windows (`stats` / `GET /metrics`
/// `routes` object). Unknown paths and wrong methods pool under
/// "other" — the label set is static, so hostile paths cannot grow the
/// metrics map.
fn route(writer: &mut TcpStream, req: &Request, shared: &Shared,
         close: bool) -> std::io::Result<()> {
    let t0 = std::time::Instant::now();
    let label: &'static str =
        match (req.method.as_str(), req.path.as_str()) {
            ("POST", "/knn") => "POST /knn",
            ("GET", "/metrics") => "GET /metrics",
            ("GET", "/healthz") => "GET /healthz",
            ("POST", "/admin/epoch-bump") => "POST /admin/epoch-bump",
            ("POST", "/admin/reshard") => "POST /admin/reshard",
            _ => "other",
        };
    let result = dispatch(writer, req, shared, close);
    record_route(shared, label, t0.elapsed());
    result
}

fn dispatch(writer: &mut TcpStream, req: &Request, shared: &Shared,
            close: bool) -> std::io::Result<()> {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/knn") => {
            let body = String::from_utf8_lossy(&req.body);
            match Json::parse(body.trim()) {
                Err(e) => {
                    let resp = Json::obj(vec![
                        ("ok", Json::Bool(false)),
                        ("error", Json::Str(format!("bad json: {e}"))),
                    ]);
                    write_response(writer, 400, "Bad Request",
                                   &resp.to_string(), &[], close)
                }
                Ok(parsed) => {
                    let resp = handle_knn(&parsed, shared);
                    let (status, reason) = status_for(&resp);
                    let mut extra: Vec<(&str, String)> = Vec::new();
                    if status == 429 {
                        extra.push(("Retry-After",
                                    retry_after_secs(&resp)));
                    }
                    write_response(writer, status, reason,
                                   &resp.to_string(), &extra, close)
                }
            }
        }
        ("GET", "/metrics") => write_response(
            writer, 200, "OK", &stats_json(shared).to_string(), &[],
            close),
        ("GET", "/healthz") => write_response(
            writer, 200, "OK",
            &Json::obj(vec![("ok", Json::Bool(true))]).to_string(), &[],
            close),
        ("POST", "/admin/epoch-bump") => write_response(
            writer, 200, "OK", &epoch_bump_json(shared).to_string(),
            &[], close),
        ("POST", "/admin/reshard") => {
            let body = String::from_utf8_lossy(&req.body);
            match Json::parse(body.trim()) {
                Err(e) => {
                    let resp = Json::obj(vec![
                        ("ok", Json::Bool(false)),
                        ("error", Json::Str(format!("bad json: {e}"))),
                    ]);
                    write_response(writer, 400, "Bad Request",
                                   &resp.to_string(), &[], close)
                }
                Ok(parsed) => {
                    let resp = reshard_json(&parsed, shared);
                    let (status, reason) = status_for(&resp);
                    write_response(writer, status, reason,
                                   &resp.to_string(), &[], close)
                }
            }
        }
        (_, "/knn") | (_, "/admin/epoch-bump") | (_, "/admin/reshard") =>
            method_not_allowed(writer, "POST", close),
        (_, "/metrics") | (_, "/healthz") => method_not_allowed(
            writer, "GET", close),
        _ => {
            let resp = Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::Str("not found".into())),
            ]);
            write_response(writer, 404, "Not Found", &resp.to_string(),
                           &[], close)
        }
    }
}

fn method_not_allowed(writer: &mut TcpStream, allow: &str, close: bool)
                      -> std::io::Result<()> {
    let resp = Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str("method not allowed".into())),
    ]);
    write_response(writer, 405, "Method Not Allowed", &resp.to_string(),
                   &[("Allow", allow.to_string())], close)
}

/// Map a serving-path JSON answer onto the HTTP status contract.
fn status_for(resp: &Json) -> (u16, &'static str) {
    if resp.get("ok") == Some(&Json::Bool(true)) {
        return (200, "OK");
    }
    match resp.get("kind").and_then(|k| k.as_str()) {
        Some("overload") => (429, "Too Many Requests"),
        Some("deadline_exceeded") => (504, "Gateway Timeout"),
        _ => {
            let msg = resp.get("error").and_then(|e| e.as_str())
                .unwrap_or("");
            // the server's fault, not the client's
            if msg.starts_with("internal error")
                || msg.starts_with("engine unavailable")
                || msg.starts_with("server shutting down")
            {
                (500, "Internal Server Error")
            } else {
                (400, "Bad Request")
            }
        }
    }
}

/// `Retry-After` is whole seconds — round the body's `retry_after_ms`
/// hint up, floor 1 s (advertising 0 would invite an immediate retry
/// storm).
fn retry_after_secs(resp: &Json) -> String {
    let ms = resp.get("retry_after_ms").and_then(|v| v.as_f64())
        .unwrap_or(1000.0) as u64;
    ms.div_ceil(1000).max(1).to_string()
}

fn write_response(writer: &mut TcpStream, status: u16, reason: &str,
                  body: &str, extra: &[(&str, String)], close: bool)
                  -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {reason}\r\n\
         Content-Type: application/json\r\n\
         Content-Length: {}\r\n",
        body.len());
    for (name, value) in extra {
        head.push_str(&format!("{name}: {value}\r\n"));
    }
    if close {
        head.push_str("Connection: close\r\n");
    }
    head.push_str("\r\n");
    writer.write_all(head.as_bytes())?;
    writer.write_all(body.as_bytes())?;
    writer.flush()
}

/// Minimal blocking HTTP client for tests and the bench harness: one
/// request per connection (`Connection: close`). Returns the status
/// code, the response headers (names lowercased) and the body.
pub fn http_request(addr: &SocketAddr, method: &str, path: &str,
                    body: Option<&str>)
                    -> std::io::Result<(u16, Vec<(String, String)>,
                                        String)> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    let body = body.unwrap_or("");
    let req = format!(
        "{method} {path} HTTP/1.1\r\n\
         Host: bmonn\r\n\
         Content-Length: {}\r\n\
         Connection: close\r\n\
         \r\n{body}",
        body.len());
    stream.write_all(req.as_bytes())?;
    stream.flush()?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw)?;
    parse_response(&raw).ok_or_else(|| {
        std::io::Error::new(std::io::ErrorKind::InvalidData,
                            "malformed http response")
    })
}

fn parse_response(raw: &[u8])
                  -> Option<(u16, Vec<(String, String)>, String)> {
    let end = find_head_end(raw)?;
    let head = String::from_utf8_lossy(&raw[..end.head_len]).into_owned();
    let mut lines = head.split("\r\n");
    let status_line = lines.next()?;
    let status: u16 =
        status_line.split_whitespace().nth(1)?.parse().ok()?;
    let headers: Vec<(String, String)> = lines
        .filter_map(|l| l.split_once(':'))
        .map(|(n, v)| (n.trim().to_ascii_lowercase(),
                       v.trim().to_string()))
        .collect();
    let body =
        String::from_utf8_lossy(&raw[end.total..]).into_owned();
    Some((status, headers, body))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn overload(ms: f64) -> Json {
        Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("kind", Json::Str("overload".into())),
            ("retry_after_ms", Json::Num(ms)),
        ])
    }

    #[test]
    fn status_contract_maps_answer_kinds() {
        let ok = Json::obj(vec![("ok", Json::Bool(true))]);
        assert_eq!(status_for(&ok).0, 200);
        assert_eq!(status_for(&overload(10.0)).0, 429);
        let late = Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("kind", Json::Str("deadline_exceeded".into())),
        ]);
        assert_eq!(status_for(&late).0, 504);
        let bad = Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("error", Json::Str("k out of range".into())),
        ]);
        assert_eq!(status_for(&bad).0, 400);
        let boom = Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("error",
             Json::Str("internal error: compute panicked".into())),
        ]);
        assert_eq!(status_for(&boom).0, 500);
    }

    #[test]
    fn retry_after_rounds_up_to_whole_seconds() {
        assert_eq!(retry_after_secs(&overload(1.0)), "1");
        assert_eq!(retry_after_secs(&overload(1000.0)), "1");
        assert_eq!(retry_after_secs(&overload(1001.0)), "2");
        assert_eq!(retry_after_secs(&overload(12_500.0)), "13");
        // a pathological 0 hint must not advertise "retry now"
        assert_eq!(retry_after_secs(&overload(0.0)), "1");
    }

    #[test]
    fn head_end_accepts_crlf_and_bare_lf() {
        let crlf = b"GET / HTTP/1.1\r\nHost: x\r\n\r\nBODY";
        let e = find_head_end(crlf).unwrap();
        assert_eq!(&crlf[e.total..], b"BODY");
        let lf = b"GET / HTTP/1.1\nHost: x\n\nBODY";
        let e = find_head_end(lf).unwrap();
        assert_eq!(&lf[e.total..], b"BODY");
        assert!(find_head_end(b"GET / HTTP/1.1\r\nHost:").is_none());
    }

    #[test]
    fn response_parser_roundtrips() {
        let raw = b"HTTP/1.1 429 Too Many Requests\r\n\
                    Content-Type: application/json\r\n\
                    Retry-After: 2\r\n\r\n{\"ok\": false}";
        let (status, headers, body) = parse_response(raw).unwrap();
        assert_eq!(status, 429);
        assert_eq!(body, "{\"ok\": false}");
        let ra = headers.iter().find(|(n, _)| n == "retry-after");
        assert_eq!(ra.map(|(_, v)| v.as_str()), Some("2"));
    }
}
