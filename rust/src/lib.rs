//! # bmonn — Bandit-based Monte Carlo Optimization for Nearest Neighbors
//!
//! A full reproduction of Bagaria, Baharav, Kamath & Tse,
//! *"Bandit-Based Monte Carlo Optimization for Nearest Neighbors"* (2018),
//! as a three-layer rust + JAX + Pallas system:
//!
//! * **L3 (rust, this crate)** — the BMO UCB coordinator: adaptive bandit
//!   scheduling of coordinate sampling, k-NN / PAC / k-means drivers, the
//!   query server, baselines (exact, uniform, LSH, NN-descent, ANNG), and
//!   the benchmark harness reproducing every figure of the paper.
//! * **L2 (jax, `python/compile/model.py`)** — fixed-shape batched-pull
//!   compute graphs, AOT-lowered once to HLO text.
//! * **L1 (pallas, `python/compile/kernels/`)** — the gather+reduce pull
//!   kernel and the FWHT rotation kernel.
//!
//! Quick start (single query):
//! ```no_run
//! use bmonn::coordinator::{BanditParams, knn::knn_point_dense};
//! use bmonn::data::{synthetic, Metric};
//! use bmonn::metrics::Counter;
//! use bmonn::runtime::native::NativeEngine;
//! use bmonn::util::rng::Rng;
//!
//! let data = synthetic::image_like(1000, 1024, 42);
//! let mut engine = NativeEngine::default();
//! let mut rng = Rng::new(0);
//! let mut counter = Counter::new();
//! let res = knn_point_dense(&data, 0, Metric::L2Sq,
//!                           &BanditParams { k: 5, ..Default::default() },
//!                           &mut engine, &mut rng, &mut counter);
//! println!("5-NN of point 0: {:?} ({} coordinate ops — exact would be {})",
//!          res.ids, counter.get(), (data.n - 1) * data.d);
//! ```
//!
//! Batched serving path — many concurrent queries advanced in lockstep,
//! their per-round coordinate pulls coalesced into one engine sweep of
//! the dataset (this is what the query server runs):
//! ```no_run
//! use bmonn::coordinator::{BanditParams, knn::knn_batch_dense};
//! use bmonn::data::{synthetic, Metric};
//! use bmonn::metrics::Counter;
//! use bmonn::runtime::native::NativeEngine;
//! use bmonn::util::rng::Rng;
//!
//! let data = synthetic::image_like(1000, 1024, 42);
//! let queries: Vec<Vec<f32>> = (0..64).map(|i| data.row_vec(i)).collect();
//! let mut engine = NativeEngine::default();
//! let mut rng = Rng::new(0);
//! let mut counter = Counter::new();
//! let results = knn_batch_dense(
//!     &data, &queries, Metric::L2Sq,
//!     &BanditParams { k: 5, ..Default::default() },
//!     &mut engine, &mut rng, &mut counter);
//! println!("{} answers in {} coordinate ops", results.len(),
//!          counter.get());
//! ```

pub mod baselines;
pub mod bench_harness;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod runtime;
pub mod util;
