//! Configuration system: layered TOML-subset files + CLI overrides.
//!
//! The offline crate set has no `toml`/`serde`, so this module implements
//! the subset the project needs: `[section]` headers, `key = value` with
//! string / number / bool values, and `#` comments. Files load into a flat
//! `section.key -> value` map; the typed `BmonnConfig` is resolved from
//! (defaults ← file ← CLI `--set section.key=value` overrides).

use std::collections::BTreeMap;
use std::path::Path;

use crate::coordinator::bandit::{BanditParams, PullPolicy, SigmaMode};
use crate::data::dense::Metric;
use crate::runtime::kernels::KernelChoice;

/// Flat key-value store parsed from a TOML-subset file.
#[derive(Clone, Debug, Default)]
pub struct RawConfig {
    values: BTreeMap<String, String>,
}

impl RawConfig {
    pub fn parse(text: &str) -> Result<RawConfig, String> {
        let mut section = String::new();
        let mut values = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: bad section", lineno + 1))?;
                section = name.trim().to_string();
            } else if let Some((k, v)) = line.split_once('=') {
                let key = if section.is_empty() {
                    k.trim().to_string()
                } else {
                    format!("{section}.{}", k.trim())
                };
                let mut val = v.trim().to_string();
                if val.starts_with('"') && val.ends_with('"') && val.len() >= 2 {
                    val = val[1..val.len() - 1].to_string();
                }
                values.insert(key, val);
            } else {
                return Err(format!("line {}: expected key = value", lineno + 1));
            }
        }
        Ok(RawConfig { values })
    }

    pub fn load(path: &Path) -> Result<RawConfig, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {path:?}: {e}"))?;
        Self::parse(&text)
    }

    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }

    pub fn merge(&mut self, other: &RawConfig) {
        for (k, v) in &other.values {
            self.values.insert(k.clone(), v.clone());
        }
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>, String> {
        self.get(key)
            .map(|v| v.parse().map_err(|_| format!("{key}: bad usize '{v}'")))
            .transpose()
    }

    pub fn get_u64(&self, key: &str) -> Result<Option<u64>, String> {
        self.get(key)
            .map(|v| v.parse().map_err(|_| format!("{key}: bad u64 '{v}'")))
            .transpose()
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>, String> {
        self.get(key)
            .map(|v| v.parse().map_err(|_| format!("{key}: bad f64 '{v}'")))
            .transpose()
    }

    pub fn get_bool(&self, key: &str) -> Result<Option<bool>, String> {
        self.get(key)
            .map(|v| match v {
                "true" | "1" | "yes" => Ok(true),
                "false" | "0" | "no" => Ok(false),
                _ => Err(format!("{key}: bad bool '{v}'")),
            })
            .transpose()
    }
}

/// Parse a shard-endpoint list (`[engine] remote` / `--remote`): one
/// entry per logical shard, separated by commas or whitespace (empty
/// entries are dropped, so a trailing comma is harmless). Each entry may
/// itself be a `|`-separated **replica list** for that shard —
/// `"a:1|b:1, c:2|d:2"` is a 2-shard ring with two replicas per shard.
/// The `|` groups are kept intact here;
/// `runtime::placement::PlacementMap::parse` splits them.
pub fn parse_endpoints(s: &str) -> Vec<String> {
    s.split(|c: char| c == ',' || c.is_whitespace())
        .map(|e| e.trim())
        .filter(|e| !e.is_empty())
        .map(|e| e.to_string())
        .collect()
}

/// Which compute engine drives batched pulls.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    Scalar,
    Native,
    Pjrt,
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<EngineKind> {
        match s {
            "scalar" => Some(EngineKind::Scalar),
            "native" => Some(EngineKind::Native),
            "pjrt" => Some(EngineKind::Pjrt),
            _ => None,
        }
    }
}

/// Fully-resolved configuration.
#[derive(Clone, Debug)]
pub struct BmonnConfig {
    pub metric: Metric,
    pub k: usize,
    pub delta: f64,
    pub epsilon: f64,
    pub sigma: SigmaMode,
    pub policy: PullPolicy,
    pub engine: EngineKind,
    /// contiguous dataset-row shards the host engine fans pull waves
    /// across (`[engine] shards` / `--shards S`); 1 = single-threaded.
    /// Sharded execution is bitwise-identical to single-threaded for any
    /// value — it only changes which core computes each row.
    pub shards: usize,
    /// shard-server endpoints (`[engine] remote = "host:p,host:p"` /
    /// `--remote`): when non-empty, pull waves fan out over this ring via
    /// `runtime::remote::RemoteEngine` instead of computing locally. The
    /// `i`-th entry names shard `i`'s servers (`bmonn shard-serve
    /// --shard i --of S`) and may be a `|`-separated replica list — on
    /// an I/O error or wire error the sub-wave transparently fails over
    /// to the shard's next live replica. Mutually exclusive with
    /// `shards`. Shard servers always compute with the native engine,
    /// so results are bitwise-identical to local *native* execution
    /// whenever any replica of every shard survives (requesting the
    /// scalar or pjrt engine together with `remote` is an error).
    pub remote: Vec<String>,
    /// degraded mode (`[engine] degraded` / `--degraded`, remote rings
    /// only): when every replica of some shard is dead, answer queries
    /// with exact distances over the surviving shards' rows and a
    /// coverage annotation instead of erroring. Off by default — a full
    /// ring outage then surfaces as query errors, never silent partial
    /// answers.
    pub degraded: bool,
    /// row-kernel tier for the native engine (`[engine] kernel` /
    /// `--kernel auto|scalar|avx2|neon`): `auto` (default) dispatches on
    /// the CPU features detected at engine construction; forcing a tier
    /// the host lacks is a startup error, never a crash mid-query.
    /// Results are bitwise-reproducible *per tier*; different tiers
    /// agree only to the parity-test tolerance (see docs/CONFIG.md).
    pub kernel: KernelChoice,
    /// opt-in int8 sampling tier (`[engine] quantized` / `--quantized`):
    /// the native engine samples coordinates from an int8 shadow copy
    /// (4x less memory bandwidth) and rescores every candidate on the
    /// exact f32 rows; the quantization error bound widens the bandit's
    /// confidence intervals so the PAC guarantee still holds. Off by
    /// default.
    pub quantized: bool,
    /// speculative cross-round wave pipelining (`[engine] speculate` /
    /// `--speculate`, pipelined engines only): after submitting round
    /// t's pull wave the batch driver predicts round t+1's likely pull
    /// set from a throwaway rng lane and submits it early, overlapping
    /// the next wave's network/compute latency with round t's
    /// retirement. Confirmed predictions are reused; mispredictions are
    /// abandoned without consuming failover attempts or deadline
    /// budget. Answers are bitwise-identical with the flag on or off;
    /// off (the default) is byte-for-byte today's lockstep behavior.
    /// Blocking (non-pipelined) engines ignore the flag.
    pub speculate: bool,
    /// placement epoch served or expected (`[engine] epoch` /
    /// `--epoch`): on `shard-serve` (flag only) the epoch the server
    /// stamps into its handshake — a never-resharded ring serves 0,
    /// the default; on a `--remote` query server a nonzero value pins
    /// the initial ring connect so endpoints carrying any other epoch
    /// are refused (restart a coordinator whose ring was resharded to
    /// epoch E with `--epoch E`). 0 (the default) adopts whatever
    /// single epoch the ring agrees on.
    pub epoch: u64,
    /// per-connection I/O timeout in milliseconds for remote rings
    /// (`[engine] io_timeout_ms` / `--io-timeout-ms`): bounds the ring
    /// client's connects, writes and per-wave reply waits, so a dead
    /// shard costs one timeout window before failover instead of a
    /// hung socket. Must be > 0; local engines ignore it.
    pub io_timeout_ms: u64,
    pub artifact_dir: String,
    pub seed: u64,
    pub server_addr: String,
    pub server_workers: usize,
    /// max queued queries a server worker coalesces into one batched pass
    pub server_batch: usize,
    /// adaptive wait-a-little batching (`[server] batch_wait_us` /
    /// `--batch-wait-us`): how long, in microseconds, a worker that
    /// drained a non-full batch lingers for more queries before
    /// computing. Trades a bounded p50 bump for fuller coalesced
    /// batches under light load; 0 (default) drains immediately.
    /// Realized batch sizes are observable via the server's `stats` op.
    pub server_batch_wait_us: u64,
    /// default per-query deadline budget in milliseconds (`[server]
    /// deadline_ms` / `--deadline-ms`): the query server answers each
    /// query within this long of arrival — queue wait, lockstep rounds
    /// and remote waves included — or returns a structured
    /// `deadline_exceeded` error. A request's own `deadline_ms` field
    /// overrides it per query; 0 (default) disables the budget.
    pub server_deadline_ms: u64,
    /// admission bound on the query server's shared queue (`[server]
    /// max_queue` / `--max-queue`): beyond this many queued queries,
    /// new arrivals are shed immediately with an `overload` error and
    /// a `retry_after_ms` hint. 0 (default) keeps the queue unbounded.
    pub server_max_queue: usize,
    /// HTTP front-door port (`[server] http_port` / `--http-port`):
    /// when set the query server additionally serves `POST /knn` /
    /// `GET /metrics` over HTTP/1.1 on the same host. Unset (default)
    /// disables HTTP; 0 binds an ephemeral port.
    pub server_http_port: Option<u16>,
    /// LRU result-cache capacity in entries (`[server] cache_entries`
    /// / `--cache-entries`): repeat queries replay their cached answer
    /// byte-identically, keyed on query/k/accuracy-mode/dataset
    /// fingerprint/placement epoch. 0 (default) disables the cache.
    pub server_cache_entries: usize,
}

impl Default for BmonnConfig {
    fn default() -> Self {
        let p = BanditParams::default();
        BmonnConfig {
            metric: Metric::L2Sq,
            k: p.k,
            delta: p.delta,
            epsilon: 0.0,
            sigma: SigmaMode::Empirical,
            policy: PullPolicy::batched(),
            engine: EngineKind::Native,
            shards: 1,
            remote: Vec::new(),
            degraded: false,
            kernel: KernelChoice::Auto,
            quantized: false,
            speculate: false,
            epoch: 0,
            io_timeout_ms: 60_000,
            artifact_dir: "artifacts".into(),
            seed: 42,
            server_addr: "127.0.0.1:7878".into(),
            server_workers: 4,
            server_batch: 8,
            server_batch_wait_us: 0,
            server_deadline_ms: 0,
            server_max_queue: 0,
            server_http_port: None,
            server_cache_entries: 0,
        }
    }
}

impl BmonnConfig {
    /// Resolve from a raw key-value layer.
    pub fn from_raw(raw: &RawConfig) -> Result<BmonnConfig, String> {
        let mut cfg = BmonnConfig::default();
        if let Some(m) = raw.get("bandit.metric") {
            cfg.metric =
                Metric::parse(m).ok_or_else(|| format!("bad metric '{m}'"))?;
        }
        if let Some(k) = raw.get_usize("bandit.k")? {
            cfg.k = k;
        }
        if let Some(d) = raw.get_f64("bandit.delta")? {
            if !(0.0..1.0).contains(&d) || d == 0.0 {
                return Err(format!("bandit.delta must be in (0,1), got {d}"));
            }
            cfg.delta = d;
        }
        if let Some(e) = raw.get_f64("bandit.epsilon")? {
            cfg.epsilon = e;
        }
        if let Some(s) = raw.get_f64("bandit.sigma")? {
            cfg.sigma = SigmaMode::Fixed(s);
        }
        if let Some(i) = raw.get_u64("policy.init_pulls")? {
            cfg.policy.init_pulls = i;
        }
        if let Some(a) = raw.get_usize("policy.round_arms")? {
            cfg.policy.round_arms = a.max(1);
        }
        if let Some(p) = raw.get_u64("policy.round_pulls")? {
            cfg.policy.round_pulls = p.max(1);
        }
        if let Some(e) = raw.get("engine.kind") {
            cfg.engine = EngineKind::parse(e)
                .ok_or_else(|| format!("bad engine '{e}'"))?;
        }
        if let Some(s) = raw.get_usize("engine.shards")? {
            cfg.shards = s.max(1);
        }
        if let Some(r) = raw.get("engine.remote") {
            cfg.remote = parse_endpoints(r);
        }
        if let Some(dg) = raw.get_bool("engine.degraded")? {
            cfg.degraded = dg;
        }
        if let Some(kc) = raw.get("engine.kernel") {
            cfg.kernel = KernelChoice::parse(kc).ok_or_else(|| {
                format!("bad kernel '{kc}' (auto|scalar|avx2|neon)")
            })?;
        }
        if let Some(qz) = raw.get_bool("engine.quantized")? {
            cfg.quantized = qz;
        }
        if let Some(sp) = raw.get_bool("engine.speculate")? {
            cfg.speculate = sp;
        }
        if let Some(e) = raw.get_u64("engine.epoch")? {
            cfg.epoch = e;
        }
        if let Some(t) = raw.get_u64("engine.io_timeout_ms")? {
            if t == 0 {
                return Err("engine.io_timeout_ms must be > 0: a zero \
                            timeout would fail every wire operation \
                            (unbounded waits are not offered — a dead \
                            peer must cost one window, not a hang)"
                    .into());
            }
            cfg.io_timeout_ms = t;
        }
        if let Some(a) = raw.get("engine.artifact_dir") {
            cfg.artifact_dir = a.to_string();
        }
        if let Some(s) = raw.get_u64("run.seed")? {
            cfg.seed = s;
        }
        if let Some(a) = raw.get("server.addr") {
            cfg.server_addr = a.to_string();
        }
        if let Some(w) = raw.get_usize("server.workers")? {
            cfg.server_workers = w.max(1);
        }
        if let Some(b) = raw.get_usize("server.batch")? {
            cfg.server_batch = b.max(1);
        }
        if let Some(w) = raw.get_u64("server.batch_wait_us")? {
            cfg.server_batch_wait_us = w;
        }
        if let Some(d) = raw.get_u64("server.deadline_ms")? {
            cfg.server_deadline_ms = d;
        }
        if let Some(m) = raw.get_usize("server.max_queue")? {
            cfg.server_max_queue = m;
        }
        if let Some(p) = raw.get_u64("server.http_port")? {
            if p > u16::MAX as u64 {
                return Err(format!(
                    "server.http_port {p} exceeds the port range"));
            }
            cfg.server_http_port = Some(p as u16);
        }
        if let Some(c) = raw.get_usize("server.cache_entries")? {
            cfg.server_cache_entries = c;
        }
        Ok(cfg)
    }

    pub fn bandit_params(&self) -> BanditParams {
        BanditParams {
            k: self.k,
            delta: self.delta,
            sigma: self.sigma,
            epsilon: self.epsilon,
            policy: self.policy,
            // estimate bias is an engine property, not a config knob:
            // the drivers raise it from PullEngine::quant_bias per query
            bias: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_toml_subset() {
        let raw = RawConfig::parse(
            "# comment\n\
             [bandit]\n\
             k = 5\n\
             delta = 0.01  # inline comment\n\
             metric = \"l1\"\n\
             [engine]\n\
             kind = native\n\
             shards = 4\n",
        )
        .unwrap();
        assert_eq!(raw.get("bandit.k"), Some("5"));
        assert_eq!(raw.get("bandit.metric"), Some("l1"));
        let cfg = BmonnConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.k, 5);
        assert_eq!(cfg.metric, Metric::L1);
        assert_eq!(cfg.engine, EngineKind::Native);
        assert_eq!(cfg.shards, 4);
    }

    #[test]
    fn remote_endpoint_list_parses() {
        let raw = RawConfig::parse(
            "[engine]\nremote = \"10.0.0.1:7979, 10.0.0.2:7979,\"\n",
        )
        .unwrap();
        let cfg = BmonnConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.remote,
                   vec!["10.0.0.1:7979".to_string(),
                        "10.0.0.2:7979".to_string()]);
        assert!(BmonnConfig::default().remote.is_empty());
        assert_eq!(parse_endpoints("  a:1  b:2 "),
                   vec!["a:1".to_string(), "b:2".to_string()]);
        assert!(parse_endpoints(" , ").is_empty());
        // replica groups stay intact within one shard's slot
        assert_eq!(parse_endpoints("a:1|b:1, c:2|d:2"),
                   vec!["a:1|b:1".to_string(), "c:2|d:2".to_string()]);
    }

    #[test]
    fn degraded_flag_parses_and_defaults_off() {
        assert!(!BmonnConfig::default().degraded);
        let raw =
            RawConfig::parse("[engine]\ndegraded = true\n").unwrap();
        assert!(BmonnConfig::from_raw(&raw).unwrap().degraded);
        let raw = RawConfig::parse("[engine]\ndegraded = maybe\n").unwrap();
        assert!(BmonnConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn batch_wait_parses_and_defaults_to_zero() {
        assert_eq!(BmonnConfig::default().server_batch_wait_us, 0);
        let raw =
            RawConfig::parse("[server]\nbatch_wait_us = 2500\n").unwrap();
        assert_eq!(BmonnConfig::from_raw(&raw).unwrap()
                       .server_batch_wait_us,
                   2500);
        let raw = RawConfig::parse("[server]\nbatch_wait_us = x\n")
            .unwrap();
        assert!(BmonnConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn kernel_and_quantized_parse_and_default_off() {
        let d = BmonnConfig::default();
        assert_eq!(d.kernel, KernelChoice::Auto);
        assert!(!d.quantized);
        let raw = RawConfig::parse(
            "[engine]\nkernel = scalar\nquantized = true\n").unwrap();
        let cfg = BmonnConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.kernel, KernelChoice::Scalar);
        assert!(cfg.quantized);
        let raw =
            RawConfig::parse("[engine]\nkernel = sse9\n").unwrap();
        assert!(BmonnConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn speculate_flag_parses_and_defaults_off() {
        assert!(!BmonnConfig::default().speculate);
        let raw =
            RawConfig::parse("[engine]\nspeculate = true\n").unwrap();
        assert!(BmonnConfig::from_raw(&raw).unwrap().speculate);
        let raw = RawConfig::parse("[engine]\nspeculate = 0\n").unwrap();
        assert!(!BmonnConfig::from_raw(&raw).unwrap().speculate);
        let raw =
            RawConfig::parse("[engine]\nspeculate = soon\n").unwrap();
        assert!(BmonnConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn epoch_parses_and_defaults_to_zero() {
        assert_eq!(BmonnConfig::default().epoch, 0);
        let raw = RawConfig::parse("[engine]\nepoch = 7\n").unwrap();
        assert_eq!(BmonnConfig::from_raw(&raw).unwrap().epoch, 7);
        let raw = RawConfig::parse("[engine]\nepoch = -1\n").unwrap();
        assert!(BmonnConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn io_timeout_parses_and_rejects_zero() {
        assert_eq!(BmonnConfig::default().io_timeout_ms, 60_000);
        let raw =
            RawConfig::parse("[engine]\nio_timeout_ms = 5000\n").unwrap();
        assert_eq!(BmonnConfig::from_raw(&raw).unwrap().io_timeout_ms,
                   5000);
        let raw = RawConfig::parse("[engine]\nio_timeout_ms = 0\n")
            .unwrap();
        assert!(BmonnConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn deadline_and_queue_bound_parse_and_default_off() {
        let d = BmonnConfig::default();
        assert_eq!(d.server_deadline_ms, 0);
        assert_eq!(d.server_max_queue, 0);
        let raw = RawConfig::parse(
            "[server]\ndeadline_ms = 250\nmax_queue = 64\n").unwrap();
        let cfg = BmonnConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.server_deadline_ms, 250);
        assert_eq!(cfg.server_max_queue, 64);
        let raw = RawConfig::parse("[server]\nmax_queue = -3\n").unwrap();
        assert!(BmonnConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn http_port_and_cache_entries_parse_and_default_off() {
        let d = BmonnConfig::default();
        assert_eq!(d.server_http_port, None);
        assert_eq!(d.server_cache_entries, 0);
        let raw = RawConfig::parse(
            "[server]\nhttp_port = 8080\ncache_entries = 512\n").unwrap();
        let cfg = BmonnConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.server_http_port, Some(8080));
        assert_eq!(cfg.server_cache_entries, 512);
        // out-of-range port is a config error, not a silent truncation
        let raw = RawConfig::parse("[server]\nhttp_port = 70000\n").unwrap();
        assert!(BmonnConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn shards_clamps_to_one() {
        let raw = RawConfig::parse("[engine]\nshards = 0\n").unwrap();
        assert_eq!(BmonnConfig::from_raw(&raw).unwrap().shards, 1);
        assert_eq!(BmonnConfig::default().shards, 1);
    }

    #[test]
    fn overrides_layer() {
        let mut raw = RawConfig::parse("[bandit]\nk = 5\n").unwrap();
        let over = RawConfig::parse("[bandit]\nk = 9\n").unwrap();
        raw.merge(&over);
        assert_eq!(BmonnConfig::from_raw(&raw).unwrap().k, 9);
    }

    #[test]
    fn rejects_bad_values() {
        let raw = RawConfig::parse("[bandit]\ndelta = 2.0\n").unwrap();
        assert!(BmonnConfig::from_raw(&raw).is_err());
        let raw2 = RawConfig::parse("[bandit]\nk = x\n").unwrap();
        assert!(BmonnConfig::from_raw(&raw2).is_err());
        assert!(RawConfig::parse("not a kv line").is_err());
    }

    #[test]
    fn fixed_sigma_mode() {
        let raw = RawConfig::parse("[bandit]\nsigma = 2.5\n").unwrap();
        let cfg = BmonnConfig::from_raw(&raw).unwrap();
        matches!(cfg.sigma, SigmaMode::Fixed(s) if (s - 2.5).abs() < 1e-12);
    }

    #[test]
    fn default_roundtrip() {
        let cfg = BmonnConfig::default();
        let p = cfg.bandit_params();
        assert_eq!(p.k, 1);
        assert!(p.epsilon == 0.0);
    }
}
