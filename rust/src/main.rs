//! `bmonn` CLI — the leader entrypoint. See `bmonn help` / cli::USAGE.

use std::path::Path;
use std::process::ExitCode;

use bmonn::baselines::{exact, uniform};
use bmonn::bench_harness::{figures, pull_bench};
use bmonn::cli::{Args, USAGE};
use bmonn::config::{parse_endpoints, BmonnConfig, EngineKind, RawConfig};
use bmonn::coordinator::kmeans::{kmeans_bmo, kmeans_exact, KMeansParams};
use bmonn::coordinator::knn::{knn_graph_dense, knn_point_dense,
                              knn_point_sparse};
use bmonn::coordinator::server::{Server, ServerConfig};
use bmonn::data::dense::Metric;
use bmonn::data::{loader, synthetic};
use bmonn::metrics::Counter;
use bmonn::runtime::build_host_engine;
use bmonn::runtime::kernels::KernelChoice;
use bmonn::runtime::native::NativeEngine;
use bmonn::runtime::partition::shard_range;
use bmonn::runtime::remote::ShardServer;
use bmonn::runtime::pjrt::{verify_exact_artifact, PjrtEngine, PjrtRuntime};
use bmonn::util::rng::Rng;

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(argv) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn load_config(args: &Args) -> Result<BmonnConfig, String> {
    let mut raw = RawConfig::default();
    if let Some(path) = args.flag("config") {
        raw.merge(&RawConfig::load(Path::new(path))?);
    }
    if let Some(sets) = args.flag("set") {
        for kv in sets.split(',') {
            let (k, v) = kv
                .split_once('=')
                .ok_or_else(|| format!("--set: expected key=value, got {kv}"))?;
            raw.set(k.trim(), v.trim());
        }
    }
    let mut cfg = BmonnConfig::from_raw(&raw)?;
    // CLI flag shorthands override the file
    if let Some(m) = args.flag("metric") {
        cfg.metric = Metric::parse(m).ok_or(format!("bad --metric {m}"))?;
    }
    cfg.k = args.flag_usize("k", cfg.k)?;
    cfg.delta = args.flag_f64("delta", cfg.delta)?;
    cfg.epsilon = args.flag_f64("epsilon", cfg.epsilon)?;
    cfg.seed = args.flag_u64("seed", cfg.seed)?;
    if let Some(e) = args.flag("engine") {
        cfg.engine =
            EngineKind::parse(e).ok_or(format!("bad --engine {e}"))?;
    }
    cfg.shards = args.flag_usize("shards", cfg.shards)?.max(1);
    if let Some(r) = args.flag("remote") {
        cfg.remote = parse_endpoints(r);
    }
    if args.flag_bool("degraded") {
        cfg.degraded = true;
    }
    if let Some(kc) = args.flag("kernel") {
        cfg.kernel = KernelChoice::parse(kc)
            .ok_or(format!("bad --kernel {kc} (auto|scalar|avx2|neon)"))?;
    }
    if args.flag_bool("quantized") {
        cfg.quantized = true;
    }
    if args.flag_bool("speculate") {
        cfg.speculate = true;
    }
    cfg.epoch = args.flag_u64("epoch", cfg.epoch)?;
    cfg.io_timeout_ms =
        args.flag_u64("io-timeout-ms", cfg.io_timeout_ms)?;
    if cfg.io_timeout_ms == 0 {
        return Err("--io-timeout-ms must be > 0: a zero timeout would \
                    fail every wire operation (unbounded waits are not \
                    offered — a dead peer must cost one window, not a \
                    hang)".into());
    }
    if let Some(a) = args.flag("artifacts") {
        cfg.artifact_dir = a.to_string();
    }
    if let Some(a) = args.flag("addr") {
        cfg.server_addr = a.to_string();
    }
    Ok(cfg)
}

fn run(argv: Vec<String>) -> Result<(), String> {
    let args = Args::parse(argv)?;
    match args.subcommand.as_str() {
        "" | "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        "gen-data" => cmd_gen_data(&args),
        "knn" => cmd_knn(&args),
        "graph" => cmd_graph(&args),
        "kmeans" => cmd_kmeans(&args),
        "serve" => cmd_serve(&args),
        "shard-serve" => cmd_shard_serve(&args),
        "ring-stats" => cmd_ring_stats(&args),
        "reshard" => cmd_reshard(&args),
        "bench" => cmd_bench(&args),
        "selftest" => cmd_selftest(&args),
        other => Err(format!("unknown subcommand '{other}'\n{USAGE}")),
    }
}

fn cmd_gen_data(args: &Args) -> Result<(), String> {
    let kind = args.flag("kind").unwrap_or("image");
    let n = args.flag_usize("n", 1000)?;
    let d = args.flag_usize("d", 1024)?;
    let seed = args.flag_u64("seed", 42)?;
    let out = args.flag("out").ok_or("--out FILE required")?;
    match kind {
        "image" => {
            let ds = synthetic::image_like(n, d, seed);
            loader::save_dense(&ds, Path::new(out))
                .map_err(|e| e.to_string())?;
        }
        "gaussian" => {
            let ds = synthetic::gaussian_means(
                n, d, args.flag_f64("mu", 4.0)?, args.flag_f64("s", 1.0)?,
                seed);
            loader::save_dense(&ds, Path::new(out))
                .map_err(|e| e.to_string())?;
        }
        "powerlaw" => {
            let ds = synthetic::power_law_gaps(
                n, d, args.flag_f64("alpha", 2.0)?, 1.0, seed);
            loader::save_dense(&ds, Path::new(out))
                .map_err(|e| e.to_string())?;
        }
        "rna" => {
            let ds = synthetic::rna_like(
                n, d, args.flag_f64("density", 0.07)?, seed);
            loader::save_sparse(&ds, Path::new(out))
                .map_err(|e| e.to_string())?;
        }
        other => return Err(format!("unknown --kind {other}")),
    }
    println!("wrote {kind} dataset n={n} d={d} -> {out}");
    Ok(())
}

fn cmd_knn(args: &Args) -> Result<(), String> {
    let cfg = load_config(args)?;
    let path = args.flag("data").ok_or("--data FILE required")?;
    let algo = args.flag("algo").unwrap_or("bmo");
    let q = args.flag_usize("query-idx", 0)?;
    let mut rng = Rng::new(cfg.seed);
    let mut counter = Counter::new();
    // sparse path
    if path.ends_with(".bms") {
        // --remote ships dense f32 rows only; route through the engine
        // builder so the rejection is the same validated error every
        // caller sees, instead of an undefined sparse-over-the-wire path
        if !cfg.remote.is_empty() {
            build_host_engine(cfg.engine, cfg.shards, &cfg.remote,
                              cfg.degraded, cfg.kernel, cfg.quantized,
                              true, None)?;
        }
        let data =
            loader::load_sparse(Path::new(path)).map_err(|e| e.to_string())?;
        let res = match algo {
            "bmo" => knn_point_sparse(&data, q, Metric::L1,
                                      &cfg.bandit_params(), &mut rng,
                                      &mut counter),
            "exact" => {
                let r = exact::knn_point_sparse(&data, q, cfg.k, Metric::L1,
                                                &mut counter);
                print_answer(&r.ids, &r.dists, counter.get());
                return Ok(());
            }
            other => return Err(format!("sparse data supports \
                                         --algo bmo|exact, got {other}")),
        };
        print_answer(&res.ids, &res.dists, counter.get());
        return Ok(());
    }
    let data =
        loader::load_dense(Path::new(path)).map_err(|e| e.to_string())?;
    let params = cfg.bandit_params();
    let batch = args.flag_usize("batch", 1)?;
    if batch > 1 {
        if algo != "bmo" {
            return Err("--batch requires --algo bmo".into());
        }
        return cmd_knn_batch(&cfg, &data, q, batch);
    }
    let mut coverage = None;
    let ids_dists: (Vec<u32>, Vec<f64>) = match algo {
        "bmo" => {
            let res = match cfg.engine {
                EngineKind::Pjrt => {
                    if cfg.shards > 1 || !cfg.remote.is_empty() {
                        return Err("--shards/--remote apply to host \
                                    engines (native|scalar), not pjrt"
                            .into());
                    }
                    let mut e = PjrtEngine::new(
                        Path::new(&cfg.artifact_dir), cfg.metric)
                        .map_err(|e| e.to_string())?;
                    // align round pulls to the artifact T
                    let mut p = params.clone();
                    p.policy.round_pulls = e.round_pulls();
                    knn_point_dense(&data, q, cfg.metric, &p, &mut e,
                                    &mut rng, &mut counter)
                }
                kind => {
                    // scalar/native; sharded across a row-partitioned
                    // worker pool when --shards > 1, or fanned over a
                    // shard-serve ring when --remote is given
                    let mut e = build_host_engine(
                        kind, cfg.shards, &cfg.remote, cfg.degraded,
                        cfg.kernel, cfg.quantized, false,
                        Some(std::time::Duration::from_millis(
                            cfg.io_timeout_ms)))?;
                    knn_point_dense(&data, q, cfg.metric, &params, &mut e,
                                    &mut rng, &mut counter)
                }
            };
            coverage = res.coverage.clone();
            (res.ids, res.dists)
        }
        "exact" => {
            let r = exact::knn_point(&data, q, cfg.k, cfg.metric,
                                     &mut counter);
            (r.ids, r.dists)
        }
        "uniform" => {
            let m = args.flag_u64("samples-per-arm", 64)?;
            let r = uniform::knn_point(&data, q, cfg.k, cfg.metric, m,
                                       &mut rng, &mut counter);
            (r.ids, r.est_dists)
        }
        "lsh" => {
            let (idx, p) = bmonn::baselines::lsh::build_tuned(
                &data, cfg.metric, cfg.k, 0.95, &mut rng);
            eprintln!("lsh tuned: {} tables", p.n_tables);
            let r = idx.knn_query(data.row(q), Some(q), cfg.k, &mut counter);
            (r.iter().map(|&(i, _)| i).collect(),
             r.iter().map(|&(_, d)| d).collect())
        }
        "kgraph" => {
            let idx = bmonn::baselines::nndescent::NnDescentIndex::build(
                &data, cfg.metric,
                bmonn::baselines::nndescent::NnDescentParams::default(),
                &mut rng);
            let r = idx.knn_query(data.row(q), Some(q), cfg.k, &mut rng,
                                  &mut counter);
            (r.iter().map(|&(i, _)| i).collect(),
             r.iter().map(|&(_, d)| d).collect())
        }
        "ngt" => {
            let idx = bmonn::baselines::graph_search::AnngIndex::build(
                &data, cfg.metric,
                bmonn::baselines::graph_search::AnngParams::default(),
                &mut rng);
            let r = idx.knn_query(data.row(q), Some(q), cfg.k, &mut rng,
                                  &mut counter);
            (r.iter().map(|&(i, _)| i).collect(),
             r.iter().map(|&(_, d)| d).collect())
        }
        other => return Err(format!("unknown --algo {other}")),
    };
    print_answer(&ids_dists.0, &ids_dists.1, counter.get());
    if let Some(cov) = &coverage {
        print_coverage(cov);
    }
    let exact_units = ((data.n - 1) * data.d) as u64;
    println!("gain vs exact: {:.1}x",
             exact_units as f64 / counter.get().max(1) as f64);
    Ok(())
}

/// `knn --batch B`: answer B consecutive query points through the
/// coalesced multi-query driver (the server's execution path).
fn cmd_knn_batch(cfg: &BmonnConfig, data: &bmonn::data::DenseDataset,
                 q0: usize, batch: usize) -> Result<(), String> {
    use bmonn::coordinator::knn::{knn_batch_points_dense_opts,
                                  BatchOptions};
    let points: Vec<usize> =
        (q0..q0 + batch).map(|i| i % data.n).collect();
    let params = cfg.bandit_params();
    let mut rng = Rng::new(cfg.seed);
    let mut counter = Counter::new();
    let opts = BatchOptions { deadline: None, speculate: cfg.speculate };
    let (results, spec) = match cfg.engine {
        EngineKind::Pjrt => {
            if cfg.shards > 1 || !cfg.remote.is_empty() {
                return Err("--shards/--remote apply to host engines \
                            (native|scalar), not pjrt".into());
            }
            let mut e =
                PjrtEngine::new(Path::new(&cfg.artifact_dir), cfg.metric)
                    .map_err(|e| e.to_string())?;
            let mut p = params.clone();
            p.policy.round_pulls = e.round_pulls();
            knn_batch_points_dense_opts(data, &points, cfg.metric, &p,
                                        &mut e, &mut rng, &mut counter,
                                        opts)
        }
        kind => {
            let mut e = build_host_engine(
                kind, cfg.shards, &cfg.remote, cfg.degraded, cfg.kernel,
                cfg.quantized, false,
                Some(std::time::Duration::from_millis(
                    cfg.io_timeout_ms)))?;
            knn_batch_points_dense_opts(data, &points, cfg.metric,
                                        &params, &mut e, &mut rng,
                                        &mut counter, opts)
        }
    };
    if spec.speculated > 0 {
        println!("speculation: {} pulls speculated, {} confirmed, {} \
                  discarded",
                 spec.speculated, spec.confirmed, spec.discarded);
    }
    for (&q, res) in points.iter().zip(&results) {
        println!("query {q}:");
        print_answer(&res.ids, &res.dists,
                     res.metrics.dist_computations);
    }
    if let Some(cov) = results.iter().find_map(|r| r.coverage.as_ref()) {
        print_coverage(cov);
    }
    let exact_units = (batch * (data.n - 1) * data.d) as u64;
    println!("batch of {batch}: {} total units, gain vs exact {:.1}x",
             counter.get(),
             exact_units as f64 / counter.get().max(1) as f64);
    Ok(())
}

fn print_answer(ids: &[u32], dists: &[f64], units: u64) {
    println!("neighbors: {ids:?}");
    println!("distances: {:?}",
             dists.iter().map(|d| (d * 1000.0).round() / 1000.0)
                  .collect::<Vec<_>>());
    println!("coordinate-distance computations: {units}");
}

/// A degraded (partial-ring) answer must never look like a full one on
/// stdout — every CLI surface that can receive one prints this.
fn print_coverage(cov: &bmonn::coordinator::arms::Coverage) {
    println!("DEGRADED: answered over {}/{} surviving rows ({:.1}% \
              coverage) — dead shards' rows were not searched",
             cov.rows_live(), cov.rows_total, 100.0 * cov.fraction());
}

fn cmd_graph(args: &Args) -> Result<(), String> {
    let cfg = load_config(args)?;
    let path = args.flag("data").ok_or("--data FILE required")?;
    let data =
        loader::load_dense(Path::new(path)).map_err(|e| e.to_string())?;
    let mut rng = Rng::new(cfg.seed);
    let mut counter = Counter::new();
    // graph construction runs on the host engines; pjrt selection falls
    // back to native here (the batched graph driver realigns pulls)
    let kind = if cfg.engine == EngineKind::Scalar {
        EngineKind::Scalar
    } else {
        EngineKind::Native
    };
    let mut engine = build_host_engine(
        kind, cfg.shards, &cfg.remote, cfg.degraded, cfg.kernel,
        cfg.quantized, false,
        Some(std::time::Duration::from_millis(cfg.io_timeout_ms)))?;
    let g = knn_graph_dense(&data, cfg.metric, &cfg.bandit_params(),
                            &mut engine, &mut rng, &mut counter);
    let exact_units = (data.n * (data.n - 1) * data.d) as u64;
    println!("k-NN graph over n={} d={} k={}", data.n, data.d, cfg.k);
    if let Some(cov) = &g.coverage {
        print_coverage(cov);
        println!("(the graph above is NOT complete: waves answered while \
                  a shard was down searched surviving rows only)");
    }
    println!("coordinate-distance computations: {}", counter.get());
    println!("gain vs exact graph construction: {:.1}x",
             exact_units as f64 / counter.get().max(1) as f64);
    if let Some(out) = args.flag("out") {
        let mut s = String::new();
        for (i, nbrs) in g.neighbors.iter().enumerate() {
            s.push_str(&format!("{i}"));
            for n in nbrs {
                s.push_str(&format!(" {n}"));
            }
            s.push('\n');
        }
        std::fs::write(out, s).map_err(|e| e.to_string())?;
        println!("graph written to {out}");
    }
    Ok(())
}

fn cmd_kmeans(args: &Args) -> Result<(), String> {
    let cfg = load_config(args)?;
    let path = args.flag("data").ok_or("--data FILE required")?;
    let data =
        loader::load_dense(Path::new(path)).map_err(|e| e.to_string())?;
    let params = KMeansParams {
        k: args.flag_usize("clusters", 100)?,
        max_iters: args.flag_usize("iters", 10)?,
        ..Default::default()
    };
    let algo = args.flag("algo").unwrap_or("bmo");
    let mut rng = Rng::new(cfg.seed);
    let res = match algo {
        "bmo" => {
            let mut engine =
                NativeEngine::with_options(cfg.kernel, cfg.quantized)?;
            kmeans_bmo(&data, &params, &mut engine, &mut rng)
        }
        "exact" => kmeans_exact(&data, &params, &mut rng),
        other => return Err(format!("unknown --algo {other}")),
    };
    println!("k-means ({algo}): {} iters, {} units, accuracy {:?}",
             res.iters, res.metrics.dist_computations, res.assign_accuracy);
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    let cfg = load_config(args)?;
    if !cfg.remote.is_empty() && cfg.engine != EngineKind::Native {
        // fail at startup, not one "engine unavailable" reply at a time
        return Err("--remote always computes with the native engine; \
                    combine it with --engine native or drop the engine \
                    flag".into());
    }
    let path = args.flag("data").ok_or("--data FILE required")?;
    let data =
        loader::load_dense(Path::new(path)).map_err(|e| e.to_string())?;
    if cfg.degraded && cfg.remote.is_empty() {
        return Err("--degraded applies to --remote rings: local engines \
                    have no shards to lose".into());
    }
    if !cfg.remote.is_empty()
        && (cfg.kernel != KernelChoice::Auto || cfg.quantized)
    {
        return Err("--kernel/--quantized tune the engines doing the \
                    computing: with --remote, pass --kernel to the \
                    shard-serve processes (--quantized is local-only)"
            .into());
    }
    let sc = ServerConfig {
        addr: cfg.server_addr.clone(),
        metric: cfg.metric,
        params: cfg.bandit_params(),
        n_workers: cfg.server_workers,
        batch_size: cfg.server_batch,
        native_engine: cfg.engine != EngineKind::Scalar,
        shards: cfg.shards,
        remote: cfg.remote.clone(),
        degraded: cfg.degraded,
        batch_wait_us: args.flag_u64("batch-wait-us",
                                     cfg.server_batch_wait_us)?,
        kernel: cfg.kernel,
        quantized: cfg.quantized,
        deadline_ms: args.flag_u64("deadline-ms",
                                   cfg.server_deadline_ms)?,
        max_queue: args.flag_usize("max-queue", cfg.server_max_queue)?,
        io_timeout_ms: cfg.io_timeout_ms,
        speculate: cfg.speculate,
        epoch: cfg.epoch,
        // Option semantics ("absent = no HTTP") don't fit flag_u64's
        // default-value shape — parse by hand
        http_port: match args.flag("http-port") {
            None => cfg.server_http_port,
            Some(p) => Some(p.parse::<u16>().map_err(|_| {
                format!("bad --http-port {p} (expected 0..=65535)")
            })?),
        },
        cache_entries: args.flag_usize("cache-entries",
                                       cfg.server_cache_entries)?,
    };
    let srv = Server::start(data, sc).map_err(|e| e.to_string())?;
    println!("bmonn serving on {} (ctrl-c to stop)", srv.addr);
    if let Some(http) = srv.http_addr {
        println!("bmonn http front door on {http} \
                  (POST /knn, GET /metrics)");
    }
    loop {
        std::thread::sleep(std::time::Duration::from_secs(1));
    }
}

/// `shard-serve`: load (or regenerate) one contiguous row shard of a
/// dataset and answer pull waves over the binary wire protocol until a
/// `shutdown` frame (or ctrl-c). A ring of `--of S` such processes —
/// shard indices 0..S — backs `--remote` on knn/graph/serve/bench pull.
fn cmd_shard_serve(args: &Args) -> Result<(), String> {
    let shard = args.flag_usize("shard", 0)?;
    let of = args.flag_usize("of", 1)?.max(1);
    if shard >= of {
        return Err(format!("--shard {shard} out of range for --of {of}"));
    }
    let addr = args.flag("addr").unwrap_or("127.0.0.1:7979");
    let kernel = match args.flag("kernel") {
        None => KernelChoice::Auto,
        Some(kc) => KernelChoice::parse(kc).ok_or(format!(
            "bad --kernel {kc} (auto|scalar|avx2|neon)"))?,
    };
    if args.flag_bool("quantized") {
        return Err("--quantized is a local-engine feature: shard \
                    servers report no bias bound over the wire for the \
                    coordinator's PAC accounting to absorb".into());
    }
    let io_timeout_ms = args.flag_u64("io-timeout-ms", 60_000)?;
    if io_timeout_ms == 0 {
        return Err("--io-timeout-ms must be > 0".into());
    }
    let timeout = Some(std::time::Duration::from_millis(io_timeout_ms));
    let epoch = args.flag_u64("epoch", 0)?;
    if args.flag_bool("staging") {
        // staging servers learn shard identity, rows AND epoch from the
        // transfer stream — flags that would pre-commit any of those
        // are contradictions, not silently-ignored noise
        if args.flag("data").is_some() || args.flag("synthetic").is_some()
        {
            return Err("--staging starts the server empty: drop \
                        --data/--synthetic (a reshard transfer installs \
                        the dataset, shard identity and epoch over the \
                        wire)".into());
        }
        if args.flag("epoch").is_some() {
            return Err("--staging takes its epoch from the transfer \
                        stream (reshard --epoch): drop --epoch here"
                .into());
        }
        let srv = ShardServer::start_staging(addr, kernel, timeout)
            .map_err(|e| e.to_string())?;
        println!("bmonn shard-serve: STAGING on {} (kernel {}) — \
                  awaiting a transfer (bmonn reshard / POST \
                  /admin/reshard); ctrl-c or a shutdown frame stops it",
                 srv.addr, kernel.as_str());
        while !srv.shutdown_requested() {
            std::thread::sleep(std::time::Duration::from_millis(100));
        }
        println!("shutdown requested, exiting");
        return Ok(());
    }
    let data = if let Some(path) = args.flag("data") {
        loader::load_dense(Path::new(path)).map_err(|e| e.to_string())?
    } else if let Some(spec) = args.flag("synthetic") {
        parse_synthetic(spec)?
    } else {
        return Err("--data FILE, --synthetic image:N:D:SEED or \
                    --staging required".into());
    };
    let srv = ShardServer::start_shard_of_with_opts(
        addr, &data, shard, of, kernel, timeout, epoch)
        .map_err(|e| e.to_string())?;
    let (a, b) = shard_range(shard, data.n, of);
    println!("bmonn shard-serve: rows [{a}, {b}) of n={} d={} on {} \
              (shard {shard}/{of}, kernel {}, placement epoch {epoch}; \
              ctrl-c or a shutdown frame stops it)",
             data.n, data.d, srv.addr, kernel.as_str());
    while !srv.shutdown_requested() {
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    println!("shutdown requested, exiting");
    Ok(())
}

/// `ring-stats`: probe every replica of every shard spec with the wire
/// `Stats` health op and print the ring's layout, per-endpoint load and
/// row coverage. Exits with an error when some shard has no live
/// replica, so scripts can gate deploys on ring health.
fn cmd_ring_stats(args: &Args) -> Result<(), String> {
    use bmonn::runtime::placement::PlacementMap;
    use bmonn::runtime::remote::endpoint_stats;
    let specs = args
        .flag("remote")
        .map(parse_endpoints)
        .ok_or("--remote SPECS required (one entry per shard; replicas \
                separated by '|')")?;
    // --io-timeout-ms is the ring-wide name for this knob; the probe's
    // original --timeout-ms stays accepted as a legacy alias
    let timeout_ms = match args.flag("io-timeout-ms") {
        Some(_) => args.flag_u64("io-timeout-ms", 5000)?,
        None => args.flag_u64("timeout-ms", 5000)?,
    };
    let timeout = std::time::Duration::from_millis(timeout_ms.max(1));
    let map = PlacementMap::parse(&specs)?;
    let mut covered_rows = 0usize;
    let mut n_total: Option<usize> = None;
    let mut dead_shards: Vec<usize> = Vec::new();
    let mut divergent_shards: Vec<usize> = Vec::new();
    // placement epochs seen across the whole ring: one placement must
    // carry exactly one epoch, or a reshard is half-landed
    let mut ring_epochs: Vec<u64> = Vec::new();
    for shard in 0..map.n_shards() {
        let mut shard_live = false;
        // dataset fingerprints of the correctly-identified live
        // replicas of this shard: they must all agree, or the replicas
        // are serving divergent data and failover would silently switch
        // datasets mid-query (RemoteEngine refuses such a replica; this
        // surfaces it at survey time)
        let mut hashes: Vec<u64> = Vec::new();
        for (ri, ep) in map.replicas(shard).iter().enumerate() {
            match endpoint_stats(ep, Some(timeout)) {
                Ok(st) => {
                    println!(
                        "shard {shard} replica {ri} {ep}: UP — serves \
                         shard {}/{} rows [{}, {}) of n={} d={}, {} live \
                         conns, fingerprint {:#018x}, epoch {}, max {} \
                         concurrent waves/conn",
                        st.shard, st.of, st.row_start, st.row_end,
                        st.n_total, st.d, st.live_conns, st.data_hash,
                        st.epoch, st.max_conn_waves);
                    if st.of != map.n_shards() || st.shard != shard {
                        // a mis-wired endpoint would fail RemoteEngine's
                        // handshake validation, so it does NOT count as
                        // a live replica of this shard
                        println!(
                            "  MISCONFIGURED: endpoint identifies as \
                             shard {}/{} but this spec lists it as shard \
                             {shard}/{} — fix --remote or restart the \
                             server with matching --shard/--of (not \
                             counted as coverage)",
                            st.shard, st.of, map.n_shards());
                    } else {
                        if !hashes.is_empty()
                            && !hashes.contains(&st.data_hash)
                        {
                            println!(
                                "  DIVERGENT: fingerprint {:#018x} \
                                 disagrees with this shard's other \
                                 replica(s) {:#018x} — the replicas are \
                                 serving different data; reload them \
                                 from one dataset",
                                st.data_hash, hashes[0]);
                        }
                        hashes.push(st.data_hash);
                        ring_epochs.push(st.epoch);
                        if !shard_live {
                            shard_live = true;
                            covered_rows += st.row_end - st.row_start;
                            n_total = n_total.or(Some(st.n_total));
                        }
                    }
                }
                Err(e) => println!("shard {shard} replica {ri} {ep}: \
                                    DOWN — {e}"),
            }
        }
        hashes.sort_unstable();
        hashes.dedup();
        if hashes.len() > 1 {
            divergent_shards.push(shard);
        }
        if !shard_live {
            dead_shards.push(shard);
        }
    }
    if let Some(n) = n_total {
        println!(
            "ring coverage: {covered_rows}/{n} rows ({:.1}%), {} of {} \
             shards live",
            100.0 * covered_rows as f64 / n.max(1) as f64,
            map.n_shards() - dead_shards.len(),
            map.n_shards());
    }
    if !divergent_shards.is_empty() {
        return Err(format!(
            "ring inconsistent: shard(s) {divergent_shards:?} have \
             replicas serving divergent dataset fingerprints — failover \
             between them would change answers; reload the replicas \
             from one dataset"));
    }
    ring_epochs.sort_unstable();
    ring_epochs.dedup();
    if ring_epochs.len() > 1 {
        return Err(format!(
            "ring inconsistent: endpoints report divergent placement \
             epochs {ring_epochs:?} — every endpoint of one placement \
             must carry one epoch; finish or roll back the reshard"));
    }
    if !dead_shards.is_empty() {
        return Err(format!(
            "ring unhealthy: shard(s) {dead_shards:?} have no live \
             replica — queries over their rows will fail (or degrade, \
             with --degraded)"));
    }
    println!("ring healthy: every shard has a live replica");
    Ok(())
}

/// `reshard`: stream a dataset onto a new placement of STAGING shard
/// servers (`shard-serve --staging`) and fingerprint-verify every
/// installed shard before it can serve. This is the offline/populate
/// half of the elastic-ring story — a live query server reshards
/// itself (flipping its workers onto the new ring and auto-bumping the
/// result-cache epoch) via the `reshard` op / `POST /admin/reshard`.
fn cmd_reshard(args: &Args) -> Result<(), String> {
    use bmonn::runtime::placement::PlacementMap;
    use bmonn::runtime::remote::reshard_to;
    let path = args.flag("data").ok_or("--data FILE required")?;
    let specs = args
        .flag("to")
        .map(parse_endpoints)
        .ok_or("--to SPECS required (one entry per shard; replicas \
                separated by '|'; every endpoint a shard-serve \
                --staging server)")?;
    let epoch = args.flag_u64("epoch", 1)?;
    let io_timeout_ms = args.flag_u64("io-timeout-ms", 60_000)?;
    if io_timeout_ms == 0 {
        return Err("--io-timeout-ms must be > 0".into());
    }
    let data =
        loader::load_dense(Path::new(path)).map_err(|e| e.to_string())?;
    let map = PlacementMap::parse(&specs)?;
    let hashes = reshard_to(
        &data, &map, epoch,
        Some(std::time::Duration::from_millis(io_timeout_ms)))?;
    for (shard, fp) in hashes.iter().enumerate() {
        println!("shard {shard}/{}: installed and verified, fingerprint \
                  {fp:#018x}",
                 map.n_shards());
    }
    println!("reshard complete: n={} d={} over {} shard(s) at placement \
              epoch {epoch}",
             data.n, data.d, map.n_shards());
    Ok(())
}

/// `kind:n:d:seed` — regenerate a synthetic dataset in-process so a ring
/// can serve bench workloads without shipping a file around.
fn parse_synthetic(spec: &str) -> Result<bmonn::data::DenseDataset, String> {
    let parts: Vec<&str> = spec.split(':').collect();
    if parts.len() != 4 {
        return Err(format!(
            "--synthetic expects kind:N:D:SEED (e.g. image:1000:256:42), \
             got '{spec}'"));
    }
    let n: usize = parts[1].parse().map_err(|_| format!("bad N '{}'",
                                                        parts[1]))?;
    let d: usize = parts[2].parse().map_err(|_| format!("bad D '{}'",
                                                        parts[2]))?;
    let seed: u64 = parts[3].parse().map_err(|_| format!("bad SEED '{}'",
                                                         parts[3]))?;
    match parts[0] {
        "image" => Ok(synthetic::image_like(n, d, seed)),
        "gaussian" => Ok(synthetic::gaussian_iid(n, d, seed)),
        other => Err(format!("unknown synthetic kind '{other}' \
                              (image|gaussian)")),
    }
}

fn cmd_bench(args: &Args) -> Result<(), String> {
    let name = args
        .positional
        .first()
        .ok_or("bench requires a figure name (e.g. fig3b, pull)")?;
    let quick = args.flag_bool("quick");
    let seed = args.flag_u64("seed", 42)?;
    let shards = args.flag_usize("shards", 1)?.max(1);
    if name == "pull" {
        // the tracked pull-phase throughput baseline: writes (overwrites)
        // BENCH_pull.json so the perf trajectory has data points
        if args.flag("shards").is_some() {
            return Err("bench pull sweeps a fixed 1/2/4 shard ladder so \
                        baselines stay comparable across runs; --shards \
                        applies to the figure benches only".into());
        }
        let smoke = args.flag_bool("smoke") || quick;
        let out = args.flag("out").unwrap_or("BENCH_pull.json");
        // loopback ring always runs; --remote adds a user-ring rung (its
        // servers must load the bench dataset: shard-serve --synthetic
        // image:N:D:SEED with the workload shape printed in the report)
        let remote = args.flag("remote").map(parse_endpoints)
            .unwrap_or_default();
        let (rep, json) = pull_bench::run_pull_bench(smoke, seed, &remote)?;
        println!("{}", rep.render());
        std::fs::write(out, format!("{json}\n"))
            .map_err(|e| e.to_string())?;
        println!("wrote {out}");
        return Ok(());
    }
    if args.flag("remote").is_some() {
        return Err("--remote applies to knn/graph/serve and bench pull; \
                    figure benches generate their workloads in-process, \
                    so no external ring can serve their rows".into());
    }
    let rep = figures::run_figure(name, quick, seed, shards)?;
    let rendered = rep.render();
    println!("{rendered}");
    if let Some(out) = args.flag("out") {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(out)
            .map_err(|e| e.to_string())?;
        f.write_all(rendered.as_bytes()).map_err(|e| e.to_string())?;
        println!("appended to {out}");
    }
    Ok(())
}

fn cmd_selftest(args: &Args) -> Result<(), String> {
    let dir = args.flag("artifacts").unwrap_or("artifacts");
    let mut rt =
        PjrtRuntime::new(Path::new(dir)).map_err(|e| e.to_string())?;
    println!("platform: {}", rt.platform());
    for metric in [Metric::L2Sq, Metric::L1] {
        let rel = verify_exact_artifact(&mut rt, metric)
            .map_err(|e| e.to_string())?;
        println!("exact_rows_{}: max rel err {rel:.2e} {}",
                 metric.name(), if rel < 1e-3 { "OK" } else { "FAIL" });
        if rel >= 1e-3 {
            return Err("artifact verification failed".into());
        }
    }
    // end-to-end: pjrt engine vs native on a real query
    let data = synthetic::image_like(256, 512, 7);
    let mut pjrt = PjrtEngine::new(Path::new(dir), Metric::L2Sq)
        .map_err(|e| e.to_string())?;
    let mut params = bmonn::coordinator::BanditParams { k: 5,
        ..Default::default() };
    params.policy.round_pulls = pjrt.round_pulls();
    let mut rng = Rng::new(1);
    let mut c = Counter::new();
    let res = knn_point_dense(&data, 0, Metric::L2Sq, &params, &mut pjrt,
                              &mut rng, &mut c);
    let truth = exact::knn_point(&data, 0, 5, Metric::L2Sq,
                                 &mut Counter::new());
    let got: std::collections::HashSet<_> = res.ids.iter().collect();
    let want: std::collections::HashSet<_> = truth.ids.iter().collect();
    println!("pjrt end-to-end 5-NN: {} ({} artifact executions)",
             if got == want { "OK" } else { "MISMATCH" }, pjrt.executions);
    if got != want {
        return Err("pjrt end-to-end mismatch".into());
    }
    println!("selftest passed");
    Ok(())
}
