//! Randomized orthonormal rotation `H D` (Lemma 3 / Appendix C-B).
//!
//! `D` is a random ±1 diagonal, `H` the orthonormal Walsh–Hadamard matrix;
//! the in-place butterfly below applies `H` in O(d log d). Rotating a
//! dataset preserves pairwise ℓ2 distances while flattening coordinate-wise
//! distance spikes, shrinking the sub-Gaussian constant of the ℓ2 Monte
//! Carlo box by up to ~d/log(nd/δ) (Lemma 3).
//!
//! This is the rust mirror of the L1 Pallas kernel in
//! `python/compile/kernels/wht.py` (same semantics; cross-checked by the
//! runtime parity tests). The coordinator uses it when the artifact bundle
//! is not loaded or d exceeds the compiled shape.

use crate::data::dense::DenseDataset;
use crate::util::rng::Rng;

/// In-place orthonormal fast Walsh–Hadamard transform.
/// `x.len()` must be a power of two.
pub fn fwht_inplace(x: &mut [f32]) {
    let d = x.len();
    assert!(d.is_power_of_two(), "FWHT requires power-of-two length");
    let mut h = 1;
    while h < d {
        let step = h * 2;
        let mut base = 0;
        while base < d {
            for i in base..base + h {
                let a = x[i];
                let b = x[i + h];
                x[i] = a + b;
                x[i + h] = a - b;
            }
            base += step;
        }
        h = step;
    }
    let scale = 1.0 / (d as f32).sqrt();
    for v in x.iter_mut() {
        *v *= scale;
    }
}

/// The random rotation `H D`: sign diagonal + orthonormal FWHT.
#[derive(Clone, Debug)]
pub struct Rotation {
    pub signs: Vec<f32>,
}

impl Rotation {
    /// Sample a rotation for dimension `d` (power of two).
    pub fn sample(d: usize, rng: &mut Rng) -> Self {
        assert!(d.is_power_of_two());
        Rotation { signs: (0..d).map(|_| rng.sign()).collect() }
    }

    pub fn d(&self) -> usize {
        self.signs.len()
    }

    /// Apply in place to one vector.
    pub fn apply_inplace(&self, x: &mut [f32]) {
        assert_eq!(x.len(), self.signs.len());
        for (v, s) in x.iter_mut().zip(&self.signs) {
            *v *= s;
        }
        fwht_inplace(x);
    }

    pub fn apply(&self, x: &[f32]) -> Vec<f32> {
        let mut out = x.to_vec();
        self.apply_inplace(&mut out);
        out
    }

    /// Rotate a whole dataset (pads to the next power of two if needed —
    /// zero padding preserves ℓ2 distances, Appendix C-B).
    pub fn rotate_dataset(ds: &DenseDataset, rng: &mut Rng)
                          -> (DenseDataset, Rotation) {
        let d_pow = ds.d.next_power_of_two();
        let padded = if d_pow == ds.d { ds.clone() } else { ds.pad_dims(d_pow) };
        let rot = Rotation::sample(d_pow, rng);
        let mut out = DenseDataset::zeros(padded.n, d_pow);
        for i in 0..padded.n {
            let mut row = padded.row_vec(i);
            rot.apply_inplace(&mut row);
            out.row_mut(i).copy_from_slice(&row);
        }
        (out, rot)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense::{dist_slices, Metric};
    use crate::util::proptest;

    #[test]
    fn fwht_matches_explicit_matrix_small() {
        // H_2 (orthonormal) = [[1,1],[1,-1]]/sqrt(2)
        let mut x = vec![3.0, 5.0];
        fwht_inplace(&mut x);
        let s = 1.0 / 2f32.sqrt();
        assert!((x[0] - 8.0 * s).abs() < 1e-6);
        assert!((x[1] - (-2.0) * s).abs() < 1e-6);
    }

    #[test]
    fn fwht_is_involution_up_to_orthonormality() {
        // orthonormal H: H(Hx) = x
        let mut x: Vec<f32> = (0..16).map(|i| i as f32 - 7.5).collect();
        let orig = x.clone();
        fwht_inplace(&mut x);
        fwht_inplace(&mut x);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn rotation_preserves_l2_distances() {
        proptest::check(50, |rng| {
            let logd = rng.below(8);
            let d = 1usize << logd;
            let a: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
            let b: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
            let rot = Rotation::sample(d, rng);
            let ar = rot.apply(&a);
            let br = rot.apply(&b);
            let before = dist_slices(&a, &b, Metric::L2Sq);
            let after = dist_slices(&ar, &br, Metric::L2Sq);
            crate::prop_assert!(
                (before - after).abs() <= 1e-3 * before.max(1.0),
                "l2 not preserved: {before} vs {after} (d={d})"
            );
            Ok(())
        });
    }

    #[test]
    fn rotation_flattens_one_hot_difference() {
        // Lemma 3's motivating case: points differing in one coordinate.
        let mut rng = Rng::new(0);
        let d = 256;
        let mut a = vec![0.0f32; d];
        a[10] = 10.0;
        let b = vec![0.0f32; d];
        let rot = Rotation::sample(d, &mut rng);
        let ar = rot.apply(&a);
        let br = rot.apply(&b);
        let max_coord_sq = ar
            .iter()
            .zip(&br)
            .map(|(x, y)| (x - y) * (x - y))
            .fold(0f32, f32::max);
        // before: max coord² = 100; after: exactly 100/d per coordinate
        assert!(max_coord_sq < 100.0 / d as f32 * 1.01,
                "max coord sq {max_coord_sq}");
    }

    #[test]
    fn rotate_dataset_pads_non_power_of_two() {
        let mut rng = Rng::new(1);
        let ds = DenseDataset::new(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let (rotated, rot) = Rotation::rotate_dataset(&ds, &mut rng);
        assert_eq!(rotated.d, 4);
        assert_eq!(rot.d(), 4);
        let mut c = crate::metrics::Counter::new();
        let before = ds.dist(0, 1, Metric::L2Sq, &mut c);
        let after = rotated.dist(0, 1, Metric::L2Sq, &mut c);
        assert!((before - after).abs() < 1e-3);
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn fwht_rejects_non_power_of_two() {
        let mut x = vec![0.0; 3];
        fwht_inplace(&mut x);
    }
}
