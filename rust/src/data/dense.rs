//! Dense row-major dataset substrate.

use crate::metrics::Counter;

/// Distance metrics supported by the separable-distance framework
/// (paper §III: any ρ(x,y) = Σ_j ρ_j(x_j, y_j) works; we ship the two the
/// evaluation uses).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    /// squared Euclidean — k-NN under ℓ2 equals k-NN under ℓ2²
    L2Sq,
    /// Manhattan / ℓ1
    L1,
}

impl Metric {
    #[inline(always)]
    pub fn coord(self, a: f32, b: f32) -> f32 {
        let d = a - b;
        match self {
            Metric::L2Sq => d * d,
            Metric::L1 => d.abs(),
        }
    }

    pub fn parse(s: &str) -> Option<Metric> {
        match s {
            "l2" | "l2sq" | "euclidean" => Some(Metric::L2Sq),
            "l1" | "manhattan" => Some(Metric::L1),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Metric::L2Sq => "l2",
            Metric::L1 => "l1",
        }
    }
}

/// Dense `n x d` dataset, row-major `Vec<f32>`.
#[derive(Clone, Debug)]
pub struct DenseDataset {
    pub n: usize,
    pub d: usize,
    data: Vec<f32>,
}

impl DenseDataset {
    pub fn new(n: usize, d: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), n * d, "data length must be n*d");
        DenseDataset { n, d, data }
    }

    pub fn zeros(n: usize, d: usize) -> Self {
        DenseDataset { n, d, data: vec![0.0; n * d] }
    }

    #[inline(always)]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    #[inline(always)]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.d..(i + 1) * self.d]
    }

    pub fn raw(&self) -> &[f32] {
        &self.data
    }

    #[inline(always)]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.d + j]
    }

    /// Exact (un-normalized) distance between two rows; counts `d` units.
    pub fn dist(&self, i: usize, j: usize, metric: Metric,
                counter: &mut Counter) -> f64 {
        counter.add(self.d as u64);
        dist_slices(self.row(i), self.row(j), metric)
    }

    /// Exact distance to an external query vector; counts `d` units.
    pub fn dist_to(&self, i: usize, query: &[f32], metric: Metric,
                   counter: &mut Counter) -> f64 {
        counter.add(self.d as u64);
        dist_slices(self.row(i), query, metric)
    }

    /// Copy of a row (for use as a detached query).
    pub fn row_vec(&self, i: usize) -> Vec<f32> {
        self.row(i).to_vec()
    }

    /// Pad columns with zeros up to `d_new` (artifact-shape alignment;
    /// zero-padding leaves both ℓ1 and ℓ2² distances unchanged).
    pub fn pad_dims(&self, d_new: usize) -> DenseDataset {
        assert!(d_new >= self.d);
        let mut out = DenseDataset::zeros(self.n, d_new);
        for i in 0..self.n {
            out.row_mut(i)[..self.d].copy_from_slice(self.row(i));
        }
        out
    }
}

/// Exact distance between two slices — the scalar reference everyone else
/// is checked against. The optimized hot-path versions live in
/// `runtime::native`.
pub fn dist_slices(a: &[f32], b: &[f32], metric: Metric) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0f64;
    match metric {
        Metric::L2Sq => {
            for (x, y) in a.iter().zip(b) {
                let d = (x - y) as f64;
                acc += d * d;
            }
        }
        Metric::L1 => {
            for (x, y) in a.iter().zip(b) {
                acc += (x - y).abs() as f64;
            }
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> DenseDataset {
        DenseDataset::new(3, 2, vec![0.0, 0.0, 3.0, 4.0, 1.0, 1.0])
    }

    #[test]
    fn row_access() {
        let ds = toy();
        assert_eq!(ds.row(1), &[3.0, 4.0]);
        assert_eq!(ds.get(2, 1), 1.0);
    }

    #[test]
    fn l2sq_distance_counts_units() {
        let ds = toy();
        let mut c = Counter::new();
        let d = ds.dist(0, 1, Metric::L2Sq, &mut c);
        assert!((d - 25.0).abs() < 1e-9);
        assert_eq!(c.get(), 2);
    }

    #[test]
    fn l1_distance() {
        let ds = toy();
        let mut c = Counter::new();
        let d = ds.dist(0, 1, Metric::L1, &mut c);
        assert!((d - 7.0).abs() < 1e-9);
    }

    #[test]
    fn padding_preserves_distances() {
        let ds = toy();
        let padded = ds.pad_dims(5);
        let mut c = Counter::new();
        assert_eq!(
            ds.dist(0, 1, Metric::L2Sq, &mut c),
            padded.dist(0, 1, Metric::L2Sq, &mut c)
        );
        assert_eq!(padded.d, 5);
    }

    #[test]
    #[should_panic(expected = "n*d")]
    fn bad_shape_panics() {
        DenseDataset::new(2, 3, vec![0.0; 5]);
    }

    #[test]
    fn metric_parse() {
        assert_eq!(Metric::parse("l2"), Some(Metric::L2Sq));
        assert_eq!(Metric::parse("manhattan"), Some(Metric::L1));
        assert_eq!(Metric::parse("cosine"), None);
    }
}
