//! Sparse (CSR) dataset substrate with the O(1) operations the sparse
//! Monte Carlo box of §IV-A / Appendix C-A requires:
//!
//!  * sample a uniformly-random nonzero coordinate of a row — O(1)
//!    (random position into the row's index slice);
//!  * membership test "is coordinate j nonzero in row i" and value lookup
//!    — O(1) via a per-row `HashMap` (the paper's "dictionary");
//!  * sparsity-aware exact distance — O(|S_i| + |S_j|) sorted-merge, with
//!    the cost counted as `|S_i| + |S_j|` units (the [`crate::metrics`]
//!    accounting contract).

use std::collections::HashMap;

use crate::data::dense::Metric;
use crate::metrics::Counter;

/// CSR sparse matrix plus per-row coordinate→value dictionaries.
#[derive(Clone, Debug)]
pub struct SparseDataset {
    pub n: usize,
    pub d: usize,
    pub indptr: Vec<usize>,
    /// sorted within each row
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
    /// per-row dictionary: coordinate -> value (the O(1) membership/lookup
    /// structure of Appendix C-A)
    dicts: Vec<HashMap<u32, f32>>,
}

impl SparseDataset {
    /// Build from per-row (sorted-or-not) index/value pairs.
    pub fn from_rows(n: usize, d: usize,
                     rows: Vec<Vec<(u32, f32)>>) -> Self {
        assert_eq!(rows.len(), n);
        let mut indptr = Vec::with_capacity(n + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        let mut dicts = Vec::with_capacity(n);
        indptr.push(0);
        for mut row in rows {
            row.sort_unstable_by_key(|&(j, _)| j);
            row.dedup_by_key(|&mut (j, _)| j);
            let mut dict = HashMap::with_capacity(row.len() * 2);
            for &(j, v) in &row {
                assert!((j as usize) < d, "column {j} out of range");
                if v != 0.0 {
                    indices.push(j);
                    values.push(v);
                    dict.insert(j, v);
                }
            }
            indptr.push(indices.len());
            dicts.push(dict);
        }
        SparseDataset { n, d, indptr, indices, values, dicts }
    }

    /// Number of nonzeros in row i (`n_i` in the paper).
    #[inline]
    pub fn nnz(&self, i: usize) -> usize {
        self.indptr[i + 1] - self.indptr[i]
    }

    pub fn total_nnz(&self) -> usize {
        self.indices.len()
    }

    pub fn density(&self) -> f64 {
        self.total_nnz() as f64 / (self.n * self.d) as f64
    }

    /// Row support (sorted coordinate ids) and values.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[a..b], &self.values[a..b])
    }

    /// O(1): value at (i, j), 0.0 when absent.
    #[inline]
    pub fn get(&self, i: usize, j: u32) -> f32 {
        self.dicts[i].get(&j).copied().unwrap_or(0.0)
    }

    /// O(1): is coordinate j in the support of row i?
    #[inline]
    pub fn contains(&self, i: usize, j: u32) -> bool {
        self.dicts[i].contains_key(&j)
    }

    /// O(1): the t-th nonzero of row i as (coordinate, value).
    #[inline]
    pub fn support_entry(&self, i: usize, t: usize) -> (u32, f32) {
        let a = self.indptr[i];
        (self.indices[a + t], self.values[a + t])
    }

    /// Sparsity-aware exact distance via sorted-support merge.
    /// Counts `|S_i| + |S_j|` units — the paper's sparse exact baseline.
    pub fn dist(&self, i: usize, j: usize, metric: Metric,
                counter: &mut Counter) -> f64 {
        let (ia, va) = self.row(i);
        let (ib, vb) = self.row(j);
        counter.add((ia.len() + ib.len()) as u64);
        merge_dist(ia, va, ib, vb, metric)
    }

    /// Densify (testing / cross-checking only).
    pub fn to_dense(&self) -> crate::data::dense::DenseDataset {
        let mut out = crate::data::dense::DenseDataset::zeros(self.n, self.d);
        for i in 0..self.n {
            let (idx, val) = self.row(i);
            for (&j, &v) in idx.iter().zip(val) {
                out.row_mut(i)[j as usize] = v;
            }
        }
        out
    }
}

/// Distance over two sorted sparse rows.
pub fn merge_dist(ia: &[u32], va: &[f32], ib: &[u32], vb: &[f32],
                  metric: Metric) -> f64 {
    let (mut p, mut q) = (0usize, 0usize);
    let mut acc = 0f64;
    while p < ia.len() && q < ib.len() {
        match ia[p].cmp(&ib[q]) {
            std::cmp::Ordering::Less => {
                acc += metric.coord(va[p], 0.0) as f64;
                p += 1;
            }
            std::cmp::Ordering::Greater => {
                acc += metric.coord(0.0, vb[q]) as f64;
                q += 1;
            }
            std::cmp::Ordering::Equal => {
                acc += metric.coord(va[p], vb[q]) as f64;
                p += 1;
                q += 1;
            }
        }
    }
    while p < ia.len() {
        acc += metric.coord(va[p], 0.0) as f64;
        p += 1;
    }
    while q < ib.len() {
        acc += metric.coord(0.0, vb[q]) as f64;
        q += 1;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::rng::Rng;

    fn toy() -> SparseDataset {
        // row0: {0: 1.0, 3: 2.0}; row1: {3: -1.0, 5: 4.0}; row2: {}
        SparseDataset::from_rows(
            3,
            8,
            vec![
                vec![(0, 1.0), (3, 2.0)],
                vec![(5, 4.0), (3, -1.0)],
                vec![],
            ],
        )
    }

    #[test]
    fn structure() {
        let ds = toy();
        assert_eq!(ds.nnz(0), 2);
        assert_eq!(ds.nnz(2), 0);
        assert_eq!(ds.get(1, 3), -1.0);
        assert_eq!(ds.get(1, 4), 0.0);
        assert!(ds.contains(0, 0));
        assert!(!ds.contains(0, 1));
        assert_eq!(ds.support_entry(1, 0), (3, -1.0));
        assert!((ds.density() - 4.0 / 24.0).abs() < 1e-12);
    }

    #[test]
    fn l1_merge_distance_and_cost() {
        let ds = toy();
        let mut c = Counter::new();
        // |1-0| + |2-(-1)| + |0-4| = 1 + 3 + 4 = 8
        let d = ds.dist(0, 1, Metric::L1, &mut c);
        assert!((d - 8.0).abs() < 1e-9);
        assert_eq!(c.get(), 4); // |S0| + |S1|
    }

    #[test]
    fn zero_rows_handled() {
        let ds = toy();
        let mut c = Counter::new();
        let d = ds.dist(0, 2, Metric::L1, &mut c);
        assert!((d - 3.0).abs() < 1e-9);
    }

    #[test]
    fn dedups_and_drops_zeros() {
        let ds = SparseDataset::from_rows(
            1, 4, vec![vec![(1, 5.0), (1, 6.0), (2, 0.0)]]);
        assert_eq!(ds.nnz(0), 1);
    }

    #[test]
    fn matches_dense_distance_property() {
        proptest::check(64, |rng: &mut Rng| {
            let d = 1 + rng.below(40);
            let mk_row = |rng: &mut Rng| -> Vec<(u32, f32)> {
                let mut row = Vec::new();
                for j in 0..d {
                    if rng.bool(0.3) {
                        row.push((j as u32, rng.gaussian() as f32));
                    }
                }
                row
            };
            let rows = vec![mk_row(rng), mk_row(rng)];
            let ds = SparseDataset::from_rows(2, d, rows);
            let dense = ds.to_dense();
            for metric in [Metric::L1, Metric::L2Sq] {
                let mut c = Counter::new();
                let a = ds.dist(0, 1, metric, &mut c);
                let b = dense.dist(0, 1, metric, &mut c);
                crate::prop_assert!(
                    (a - b).abs() < 1e-4,
                    "sparse {a} != dense {b} (metric {metric:?}, d={d})"
                );
            }
            Ok(())
        });
    }
}
