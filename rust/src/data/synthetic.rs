//! Synthetic dataset generators standing in for the paper's datasets
//! (each generator's doc comment explains what property of the real
//! dataset it substitutes for).
//!
//! * [`image_like`] — Tiny-ImageNet stand-in: smooth, channel-correlated
//!   random fields. What BMO-NN is sensitive to is the *coordinate-wise
//!   distance distribution* (light tails, Fig 4c) and the gap structure of
//!   the θ's; low-pass-filtered textures reproduce both.
//! * [`rna_like`] — 10x-Genomics scRNA-seq stand-in: ~7%-dense CSR counts
//!   with power-law gene popularity and log-normal expression.
//! * [`gaussian_means`] — Proposition 1's generative model: points placed
//!   so that θ_i ~ N(μ, s²) exactly.
//! * [`power_law_gaps`] — Corollary 1's model: gaps Δ_i with CDF Δ^α.
//! * [`clustered`] — Gaussian mixture for the k-means experiments (Fig 5).

use crate::data::dense::DenseDataset;
use crate::data::sparse::SparseDataset;
use crate::util::rng::Rng;

/// Plain iid N(0,1) dataset.
pub fn gaussian_iid(n: usize, d: usize, seed: u64) -> DenseDataset {
    let mut rng = Rng::new(seed);
    let data = (0..n * d).map(|_| rng.gaussian() as f32).collect();
    DenseDataset::new(n, d, data)
}

/// Place point 0 at the origin and points 1..n at controlled normalized
/// distances: θ_i = ‖x_i‖²/d ~ N(mu, s²), truncated to ≥ `floor`.
///
/// Construction: draw g ~ N(0, I_d), scale to ‖x_i‖² = d·θ_i exactly.
/// Coordinates are then ~N(0, θ_i): light-tailed, matching the paper's
/// sub-Gaussian assumption, with E[X_i] = θ_i for the ℓ2² MC box.
pub fn gaussian_means(n: usize, d: usize, mu: f64, s: f64, seed: u64)
                      -> DenseDataset {
    let mut rng = Rng::new(seed);
    let mut ds = DenseDataset::zeros(n, d);
    for i in 1..n {
        let theta = (mu + s * rng.gaussian()).max(0.05 * mu.max(0.1));
        scale_row_to_theta(&mut ds, i, theta, &mut rng);
    }
    ds
}

/// Corollary 1's model: θ_0's best gap structure. Arm i has
/// θ_i = base + Δ_i with Δ_i ~ F(Δ) = Δ^α on (0, 1] (arm 1 gets Δ=0, so
/// it is the unique nearest neighbor at θ = base).
pub fn power_law_gaps(n: usize, d: usize, alpha: f64, base: f64, seed: u64)
                      -> DenseDataset {
    let mut rng = Rng::new(seed);
    let mut ds = DenseDataset::zeros(n, d);
    for i in 1..n {
        let delta = if i == 1 { 0.0 } else { rng.power_law(alpha) };
        scale_row_to_theta(&mut ds, i, base + delta, &mut rng);
    }
    ds
}

fn scale_row_to_theta(ds: &mut DenseDataset, i: usize, theta: f64,
                      rng: &mut Rng) {
    let d = ds.d;
    let row = ds.row_mut(i);
    let mut norm_sq = 0f64;
    for v in row.iter_mut() {
        let g = rng.gaussian();
        *v = g as f32;
        norm_sq += g * g;
    }
    let scale = ((theta * d as f64) / norm_sq.max(1e-30)).sqrt() as f32;
    for v in row.iter_mut() {
        *v *= scale;
    }
}

/// Tiny-ImageNet stand-in: each point is a smooth 1-D "texture" — a sum of
/// low-frequency cosines with 1/f amplitudes around a cluster prototype,
/// plus mild pixel noise. Values roughly in [0, 1] like normalized pixels.
pub fn image_like(n: usize, d: usize, seed: u64) -> DenseDataset {
    let n_clusters = (n / 50).clamp(4, 64);
    image_like_clustered(n, d, n_clusters, seed)
}

/// Image-like data with an explicit number of prototype clusters.
pub fn image_like_clustered(n: usize, d: usize, n_clusters: usize,
                            seed: u64) -> DenseDataset {
    let mut rng = Rng::new(seed);
    let n_freq = 12usize.min(d.max(2) / 2).max(1);
    // cluster prototypes: amplitude/phase per frequency
    let protos: Vec<Vec<(f64, f64, f64)>> = (0..n_clusters)
        .map(|_| {
            (1..=n_freq)
                .map(|f| {
                    let amp = rng.range_f64(0.5, 1.5) / f as f64;
                    let phase = rng.range_f64(0.0, std::f64::consts::TAU);
                    (f as f64, amp, phase)
                })
                .collect()
        })
        .collect();
    let mut ds = DenseDataset::zeros(n, d);
    for i in 0..n {
        let proto = &protos[rng.below(n_clusters)];
        // per-image jitter of the prototype
        let jitter: Vec<(f64, f64, f64)> = proto
            .iter()
            .map(|&(f, a, p)| {
                (f,
                 a * rng.range_f64(0.8, 1.2),
                 p + rng.range_f64(-0.3, 0.3))
            })
            .collect();
        let noise_amp = 0.05;
        let row = ds.row_mut(i);
        for (j, v) in row.iter_mut().enumerate() {
            let x = j as f64 / d as f64 * std::f64::consts::TAU;
            let mut val = 0.5;
            for &(f, a, p) in &jitter {
                val += 0.25 * a * (f * x + p).cos();
            }
            val += noise_amp * rng.gaussian();
            *v = val as f32;
        }
    }
    ds
}

/// scRNA-seq stand-in: sparse nonnegative counts with cell-type
/// structure.
///
/// * a shared "housekeeping" backbone: gene popularity ∝ (j+1)^(-0.8),
///   carrying ~60% of the target density;
/// * 8 cell types, each with its own marker-gene set carrying the
///   remaining ~40% — same-type cells share supports and expression,
///   giving the near/far gap structure real single-cell data has (and
///   that BMO-NN's adaptivity exploits);
/// * expressed values are log1p-normalized counts (0.5 + |N(0,1)|) — the
///   standard scanpy-style log transform that real pipelines apply, which
///   is also what keeps the coordinate-distance tails sub-Gaussian
///   (Fig 4c); marker genes are boosted ~3x.
pub fn rna_like(n: usize, d: usize, density: f64, seed: u64)
                -> SparseDataset {
    let mut rng = Rng::new(seed);
    let n_types = 24usize.min(n / 6).max(2);
    // backbone popularity, normalized to 0.6 * density * d expected nnz
    let mut w: Vec<f64> = (0..d).map(|j| 1.0 / (j as f64 + 1.0).powf(0.8))
        .collect();
    let wsum: f64 = w.iter().sum();
    let backbone_target = 0.3 * density * d as f64;
    for x in w.iter_mut() {
        *x = (*x / wsum * backbone_target).min(0.95);
    }
    // marker sets: each type gets d/10 marker genes expressed with a flat
    // probability chosen to add the remaining 0.4 * density * d nnz
    let marker_count = (d / 10).max(1);
    let marker_p = (0.7 * density * d as f64 / marker_count as f64).min(0.95);
    let markers: Vec<Vec<usize>> = (0..n_types)
        .map(|_| rng.sample_distinct(d, marker_count))
        .collect();
    let rows = (0..n)
        .map(|i| {
            // balanced round-robin type assignment: every type has
            // ~n/n_types members, so a cell's k-NN are always same-type
            // (no orphan cells whose neighbors are all inter-type ties)
            let ty = i % n_types;
            let mut row = Vec::new();
            for (j, &p) in w.iter().enumerate() {
                if rng.bool(p) {
                    let v = (1.0 + 0.3 * rng.gaussian().abs()) as f32;
                    row.push((j as u32, v));
                }
            }
            for &j in &markers[ty] {
                if rng.bool(marker_p) {
                    let v = (2.5 * (1.0 + 0.3 * rng.gaussian().abs())) as f32;
                    row.push((j as u32, v));
                }
            }
            // from_rows dedups; keep the marker value when both fire
            row
        })
        .collect();
    SparseDataset::from_rows(n, d, rows)
}

/// Gaussian mixture with `k` well-separated centers (k-means workloads).
/// Returns (dataset, true assignment).
pub fn clustered(n: usize, d: usize, k: usize, spread: f64, seed: u64)
                 -> (DenseDataset, Vec<usize>) {
    let mut rng = Rng::new(seed);
    let centers: Vec<Vec<f32>> = (0..k)
        .map(|_| (0..d).map(|_| (rng.gaussian() * 3.0) as f32).collect())
        .collect();
    let mut ds = DenseDataset::zeros(n, d);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let c = rng.below(k);
        labels.push(c);
        let row = ds.row_mut(i);
        for (j, v) in row.iter_mut().enumerate() {
            *v = centers[c][j] + (rng.gaussian() * spread) as f32;
        }
    }
    (ds, labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense::Metric;
    use crate::metrics::Counter;

    #[test]
    fn gaussian_means_hits_target_thetas() {
        let (n, d, mu, s) = (64, 512, 4.0, 0.5);
        let ds = gaussian_means(n, d, mu, s, 7);
        let mut c = Counter::new();
        let mut thetas: Vec<f64> = (1..n)
            .map(|i| ds.dist(0, i, Metric::L2Sq, &mut c) / d as f64)
            .collect();
        let mean: f64 = thetas.iter().sum::<f64>() / thetas.len() as f64;
        assert!((mean - mu).abs() < 0.5, "mean theta {mean}");
        thetas.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // spread should be on the order of s
        let spread = thetas[thetas.len() - 1] - thetas[0];
        assert!(spread > s, "spread {spread}");
    }

    #[test]
    fn power_law_arm1_is_nearest() {
        let ds = power_law_gaps(32, 128, 2.0, 1.0, 8);
        let mut c = Counter::new();
        let d0 = ds.dist(0, 1, Metric::L2Sq, &mut c) / 128.0;
        for i in 2..32 {
            let di = ds.dist(0, i, Metric::L2Sq, &mut c) / 128.0;
            assert!(di >= d0 - 1e-6, "arm {i}: {di} < {d0}");
        }
        assert!((d0 - 1.0).abs() < 1e-3);
    }

    #[test]
    fn image_like_is_smooth_and_bounded() {
        let ds = image_like(20, 256, 9);
        // smoothness: mean |x[j+1]-x[j]| much smaller than value range
        let mut total_step = 0f64;
        let mut count = 0u64;
        for i in 0..ds.n {
            let row = ds.row(i);
            for j in 1..ds.d {
                total_step += (row[j] - row[j - 1]).abs() as f64;
                count += 1;
            }
        }
        let mean_step = total_step / count as f64;
        assert!(mean_step < 0.2, "mean step {mean_step}");
        for i in 0..ds.n {
            for &v in ds.row(i) {
                assert!((-2.0..3.0).contains(&v), "pixel {v}");
            }
        }
    }

    #[test]
    fn rna_like_density_close_to_target() {
        let ds = rna_like(200, 1000, 0.07, 10);
        let dens = ds.density();
        assert!((dens - 0.07).abs() < 0.02, "density {dens}");
        // all values nonnegative
        assert!(ds.values.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn clustered_labels_match_geometry() {
        let (ds, labels) = clustered(100, 16, 4, 0.1, 11);
        // points with equal labels should be closer on average
        let mut c = Counter::new();
        let (mut same, mut same_n, mut diff, mut diff_n) = (0f64, 0u64, 0f64, 0u64);
        for i in 0..40 {
            for j in (i + 1)..40 {
                let dist = ds.dist(i, j, Metric::L2Sq, &mut c);
                if labels[i] == labels[j] {
                    same += dist;
                    same_n += 1;
                } else {
                    diff += dist;
                    diff_n += 1;
                }
            }
        }
        if same_n > 0 && diff_n > 0 {
            assert!(same / same_n as f64 * 4.0 < diff / diff_n as f64);
        }
    }

    #[test]
    fn generators_are_deterministic() {
        let a = image_like(10, 64, 42);
        let b = image_like(10, 64, 42);
        assert_eq!(a.raw(), b.raw());
        let c = image_like(10, 64, 43);
        assert_ne!(a.raw(), c.raw());
    }
}
