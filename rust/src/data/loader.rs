//! Binary dataset I/O.
//!
//! Two tiny little-endian formats so benchmark workloads can be generated
//! once (`bmonn gen-data`) and shared between runs:
//!
//! * dense:  magic `BMD1` | u64 n | u64 d | n*d f32
//! * sparse: magic `BMS1` | u64 n | u64 d | u64 nnz | (n+1) u64 indptr
//!           | nnz u32 indices | nnz f32 values

use std::fs::File;
use std::io::{self, BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::data::dense::DenseDataset;
use crate::data::sparse::SparseDataset;

const DENSE_MAGIC: &[u8; 4] = b"BMD1";
const SPARSE_MAGIC: &[u8; 4] = b"BMS1";

fn write_u64<W: Write>(w: &mut W, x: u64) -> io::Result<()> {
    w.write_all(&x.to_le_bytes())
}

fn read_u64<R: Read>(r: &mut R) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

pub fn save_dense(ds: &DenseDataset, path: &Path) -> io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(DENSE_MAGIC)?;
    write_u64(&mut w, ds.n as u64)?;
    write_u64(&mut w, ds.d as u64)?;
    for &v in ds.raw() {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

pub fn load_dense(path: &Path) -> io::Result<DenseDataset> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != DENSE_MAGIC {
        return Err(bad("not a BMD1 dense dataset"));
    }
    let n = read_u64(&mut r)? as usize;
    let d = read_u64(&mut r)? as usize;
    let mut buf = vec![0u8; n * d * 4];
    r.read_exact(&mut buf)?;
    let data = buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(DenseDataset::new(n, d, data))
}

pub fn save_sparse(ds: &SparseDataset, path: &Path) -> io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(SPARSE_MAGIC)?;
    write_u64(&mut w, ds.n as u64)?;
    write_u64(&mut w, ds.d as u64)?;
    write_u64(&mut w, ds.total_nnz() as u64)?;
    for &p in &ds.indptr {
        write_u64(&mut w, p as u64)?;
    }
    for &i in &ds.indices {
        w.write_all(&i.to_le_bytes())?;
    }
    for &v in &ds.values {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

pub fn load_sparse(path: &Path) -> io::Result<SparseDataset> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != SPARSE_MAGIC {
        return Err(bad("not a BMS1 sparse dataset"));
    }
    let n = read_u64(&mut r)? as usize;
    let d = read_u64(&mut r)? as usize;
    let nnz = read_u64(&mut r)? as usize;
    let mut indptr = Vec::with_capacity(n + 1);
    for _ in 0..=n {
        indptr.push(read_u64(&mut r)? as usize);
    }
    if *indptr.last().unwrap() != nnz {
        return Err(bad("indptr/nnz mismatch"));
    }
    let mut ibuf = vec![0u8; nnz * 4];
    r.read_exact(&mut ibuf)?;
    let mut vbuf = vec![0u8; nnz * 4];
    r.read_exact(&mut vbuf)?;
    // Rebuild through from_rows to regenerate the O(1) dictionaries.
    let indices: Vec<u32> = ibuf
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let values: Vec<f32> = vbuf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let rows = (0..n)
        .map(|i| {
            (indptr[i]..indptr[i + 1])
                .map(|p| (indices[p], values[p]))
                .collect()
        })
        .collect();
    Ok(SparseDataset::from_rows(n, d, rows))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn dense_roundtrip() {
        let ds = synthetic::gaussian_iid(17, 9, 1);
        let path = std::env::temp_dir().join("bmonn_test_dense.bmd");
        save_dense(&ds, &path).unwrap();
        let back = load_dense(&path).unwrap();
        assert_eq!(ds.n, back.n);
        assert_eq!(ds.d, back.d);
        assert_eq!(ds.raw(), back.raw());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sparse_roundtrip() {
        let ds = synthetic::rna_like(23, 101, 0.1, 2);
        let path = std::env::temp_dir().join("bmonn_test_sparse.bms");
        save_sparse(&ds, &path).unwrap();
        let back = load_sparse(&path).unwrap();
        assert_eq!(ds.n, back.n);
        assert_eq!(ds.d, back.d);
        assert_eq!(ds.indptr, back.indptr);
        assert_eq!(ds.indices, back.indices);
        assert_eq!(ds.values, back.values);
        assert_eq!(back.get(0, back.indices.first().copied().unwrap_or(0)),
                   ds.get(0, ds.indices.first().copied().unwrap_or(0)));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_wrong_magic() {
        let path = std::env::temp_dir().join("bmonn_test_bad.bmd");
        std::fs::write(&path, b"NOPE0000").unwrap();
        assert!(load_dense(&path).is_err());
        assert!(load_sparse(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
