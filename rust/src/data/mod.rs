//! Data substrate: dense/sparse datasets, synthetic generators, binary
//! I/O, and the randomized Hadamard rotation.

pub mod dense;
pub mod loader;
pub mod rotate;
pub mod sparse;
pub mod synthetic;

pub use dense::{DenseDataset, Metric};
pub use sparse::SparseDataset;
