//! Metrics substrate: the paper's universal cost unit plus timers.
//!
//! Every algorithm in this repo (BMO-NN and all baselines) accounts its
//! work in **coordinate-wise distance computations** through [`Counter`],
//! mirroring the paper's Appendix D accounting: one unit per sampled
//! coordinate, `d` (or `|S_i| + |S_j|` for sparse rows) per exact
//! distance. Wall-clock figures use [`Stopwatch`];
//! distributional figures (Fig 4c / Fig 7) use [`Histogram`].

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Monotonic counter of coordinate-wise distance computations.
///
/// Plain `u64` cell — the coordinator is single-threaded per query; the
/// server gives each worker its own counter and merges.
#[derive(Clone, Debug, Default)]
pub struct Counter {
    count: u64,
}

impl Counter {
    pub fn new() -> Self {
        Counter { count: 0 }
    }

    #[inline]
    pub fn add(&mut self, units: u64) {
        self.count += units;
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.count
    }

    pub fn reset(&mut self) {
        self.count = 0;
    }

    pub fn merge(&mut self, other: &Counter) {
        self.count += other.count;
    }
}

/// Fixed-bin histogram over `[lo, hi)` with overflow/underflow buckets.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
    pub count: u64,
    pub sum: f64,
    pub max: f64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram {
            lo,
            hi,
            bins: vec![0; nbins],
            underflow: 0,
            overflow: 0,
            count: 0,
            sum: 0.0,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn record(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        if x > self.max {
            self.max = x;
        }
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let b = ((x - self.lo) / (self.hi - self.lo)
                * self.bins.len() as f64) as usize;
            let last = self.bins.len() - 1;
            self.bins[b.min(last)] += 1;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Approximate quantile from bin counts (q in [0,1]).
    pub fn quantile(&self, q: f64) -> f64 {
        let target = (q * self.count as f64) as u64;
        let mut acc = self.underflow;
        if acc >= target {
            return self.lo;
        }
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        for (i, &c) in self.bins.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.lo + w * (i as f64 + 1.0);
            }
        }
        self.hi
    }

    /// Fraction of mass at or above `x` — used for tail comparisons
    /// (Fig 4c / Fig 7: "rapidly decaying tails").
    pub fn tail_fraction(&self, x: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        let mut acc = self.overflow;
        for (i, &c) in self.bins.iter().enumerate().rev() {
            let bin_lo = self.lo + w * i as f64;
            if bin_lo < x {
                break;
            }
            acc += c;
        }
        acc as f64 / self.count as f64
    }

    /// Render an ASCII sparkline of the (normalized) bin mass.
    pub fn sparkline(&self) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.bins.iter().copied().max().unwrap_or(0).max(1) as f64;
        self.bins
            .iter()
            .map(|&c| GLYPHS[((c as f64 / max) * 7.0).round() as usize])
            .collect()
    }
}

/// Simple stopwatch with named laps.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
    laps: Vec<(String, Duration)>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch { start: Instant::now(), laps: Vec::new() }
    }

    pub fn lap(&mut self, name: &str) -> Duration {
        let d = self.start.elapsed();
        self.laps.push((name.to_string(), d));
        self.start = Instant::now();
        d
    }

    pub fn laps(&self) -> &[(String, Duration)] {
        &self.laps
    }
}

/// Aggregated per-run metrics returned by the coordinator.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    /// coordinate-wise distance computations (the paper's unit)
    pub dist_computations: u64,
    /// number of bandit rounds (priority-queue iterations)
    pub rounds: u64,
    /// arms resolved by exact evaluation (hit MAX_PULLS)
    pub exact_evals: u64,
    /// wall time
    pub elapsed: Duration,
}

impl RunMetrics {
    pub fn merge(&mut self, o: &RunMetrics) {
        self.dist_computations += o.dist_computations;
        self.rounds += o.rounds;
        self.exact_evals += o.exact_evals;
        self.elapsed += o.elapsed;
    }

    /// Gain over exact computation that would cost `exact_units`.
    pub fn gain_vs(&self, exact_units: u64) -> f64 {
        exact_units as f64 / self.dist_computations.max(1) as f64
    }
}

/// Retained-sample window of [`LatencyStats`]: percentiles are read over
/// the most recent this-many samples. Lifetime count and mean are exact
/// regardless.
pub const LATENCY_WINDOW: usize = 4096;

/// Latency recorder for the serving driver (E12).
///
/// Memory is O(window), not O(queries served): samples land in a
/// fixed-capacity ring buffer (capacity [`LATENCY_WINDOW`] by default,
/// overridable with [`LatencyStats::with_window`]), so a long-running
/// server's percentiles track *recent* behavior and a week of traffic
/// cannot grow the tracker. `count` and `mean` stay exact over the
/// whole lifetime via running totals.
///
/// Percentiles use the nearest-rank definition
/// (`index = ceil(p/100 · n)`, 1-based): p99 of two samples reads the
/// *larger* one. The previous floor-index formula systematically read
/// low on small sample counts — p99 of `{10, 1000}` reported 10.
#[derive(Clone, Debug)]
pub struct LatencyStats {
    /// Most recent samples in ring order (not chronological once full).
    window: Vec<u64>,
    /// Next overwrite slot once `window` has reached capacity.
    next: usize,
    cap: usize,
    /// Lifetime sample count, including overwritten samples.
    total: u64,
    /// Lifetime sum in µs, for the exact all-time mean.
    sum_us: u128,
}

impl Default for LatencyStats {
    fn default() -> Self {
        Self::with_window(LATENCY_WINDOW)
    }
}

impl LatencyStats {
    /// A recorder retaining at most `cap` samples (`cap` floors at 1).
    pub fn with_window(cap: usize) -> Self {
        LatencyStats { window: Vec::new(), next: 0, cap: cap.max(1),
                       total: 0, sum_us: 0 }
    }

    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        self.total += 1;
        self.sum_us += us as u128;
        self.push_retained(us);
    }

    fn push_retained(&mut self, us: u64) {
        if self.window.len() < self.cap {
            self.window.push(us);
        } else {
            self.window[self.next] = us;
            self.next = (self.next + 1) % self.cap;
        }
    }

    /// Fold `other` into `self`: lifetime totals add exactly; `other`'s
    /// *retained* samples enter this window (subject to this capacity).
    pub fn merge(&mut self, other: &LatencyStats) {
        for &us in &other.window {
            self.push_retained(us);
        }
        self.total += other.total;
        self.sum_us += other.sum_us;
    }

    /// Lifetime sample count (retained window may be smaller).
    pub fn count(&self) -> usize {
        self.total as usize
    }

    /// Samples currently retained for percentile reads (≤ the window).
    pub fn retained(&self) -> usize {
        self.window.len()
    }

    /// Nearest-rank percentile over the retained window.
    pub fn percentile(&self, p: f64) -> Duration {
        if self.window.is_empty() {
            return Duration::ZERO;
        }
        let mut s = self.window.clone();
        s.sort_unstable();
        let n = s.len();
        let rank = ((p / 100.0) * n as f64).ceil() as usize;
        Duration::from_micros(s[rank.clamp(1, n) - 1])
    }

    /// Exact lifetime mean (running sum, unaffected by the window).
    pub fn mean(&self) -> Duration {
        if self.total == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros((self.sum_us / self.total as u128) as u64)
    }
}

/// Per-batch accounting for the batched serving path: how many coalesced
/// passes ran, how full they were, and how long each took wall-clock.
/// The server merges one record per worker pass; `stats` reports the
/// aggregate so operators can see whether dynamic batching is actually
/// amortizing work (mean batch ≈ 1 means the queue never backs up).
#[derive(Clone, Debug, Default)]
pub struct BatchStats {
    batches: u64,
    queries: u64,
    max_batch: u64,
    shed: u64,
    deadline_exceeded: u64,
    speculated: u64,
    spec_confirmed: u64,
    spec_discarded: u64,
    lat: LatencyStats,
}

impl BatchStats {
    pub fn record(&mut self, batch_size: usize, elapsed: Duration) {
        self.batches += 1;
        self.queries += batch_size as u64;
        self.max_batch = self.max_batch.max(batch_size as u64);
        self.lat.record(elapsed);
    }

    /// Record `n` queries shed at admission (queue depth over the bound).
    pub fn record_shed(&mut self, n: u64) {
        self.shed += n;
    }

    /// Record `n` queries that answered a `deadline_exceeded` error
    /// (budget expired in the queue or mid-compute).
    pub fn record_deadline_exceeded(&mut self, n: u64) {
        self.deadline_exceeded += n;
    }

    /// Record one batched pass's speculative-pipelining outcome:
    /// `speculated` per-query pulls submitted early, of which
    /// `confirmed` matched the real round (their results were reused)
    /// and the rest were discarded. `speculated == confirmed +
    /// discarded` always; all three stay 0 with speculation off.
    pub fn record_speculation(&mut self, speculated: u64, confirmed: u64,
                              discarded: u64) {
        self.speculated += speculated;
        self.spec_confirmed += confirmed;
        self.spec_discarded += discarded;
    }

    /// Speculative per-query pulls submitted ahead of their round.
    pub fn speculated(&self) -> u64 {
        self.speculated
    }

    /// Speculative pulls whose prediction matched and were reused.
    pub fn spec_confirmed(&self) -> u64 {
        self.spec_confirmed
    }

    /// Speculative pulls abandoned on misprediction.
    pub fn spec_discarded(&self) -> u64 {
        self.spec_discarded
    }

    /// Queries shed at admission since startup.
    pub fn shed(&self) -> u64 {
        self.shed
    }

    /// Queries that exceeded their deadline budget since startup.
    pub fn deadline_exceeded(&self) -> u64 {
        self.deadline_exceeded
    }

    pub fn batches(&self) -> u64 {
        self.batches
    }

    pub fn queries(&self) -> u64 {
        self.queries
    }

    pub fn max_batch(&self) -> u64 {
        self.max_batch
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.queries as f64 / self.batches as f64
        }
    }

    /// Per-batch wall-clock latency distribution.
    pub fn latency(&self) -> &LatencyStats {
        &self.lat
    }
}

/// Named scalar metrics collected during a bench run; printed as a table.
#[derive(Clone, Debug, Default)]
pub struct Registry {
    values: BTreeMap<String, f64>,
}

impl Registry {
    pub fn set(&mut self, name: &str, v: f64) {
        self.values.insert(name.to_string(), v);
    }

    pub fn get(&self, name: &str) -> Option<f64> {
        self.values.get(name).copied()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &f64)> {
        self.values.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates_and_merges() {
        let mut a = Counter::new();
        a.add(5);
        a.add(7);
        assert_eq!(a.get(), 12);
        let mut b = Counter::new();
        b.add(3);
        a.merge(&b);
        assert_eq!(a.get(), 15);
        a.reset();
        assert_eq!(a.get(), 0);
    }

    #[test]
    fn histogram_bins_and_quantiles() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..100 {
            h.record(i as f64 / 10.0); // 0.0 .. 9.9 uniform
        }
        assert_eq!(h.count, 100);
        assert_eq!(h.underflow, 0);
        assert_eq!(h.overflow, 0);
        assert!((h.mean() - 4.95).abs() < 1e-9);
        let med = h.quantile(0.5);
        assert!((4.0..=6.0).contains(&med), "median {med}");
    }

    #[test]
    fn histogram_tail_fraction() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        for i in 0..10 {
            h.record(i as f64 / 10.0 + 0.05);
        }
        let tail = h.tail_fraction(0.8);
        assert!((tail - 0.2).abs() < 1e-9, "tail {tail}");
    }

    #[test]
    fn histogram_overflow_underflow() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.record(-1.0);
        h.record(2.0);
        h.record(0.5);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.count, 3);
    }

    #[test]
    fn latency_percentiles() {
        let mut l = LatencyStats::default();
        for i in 1..=100u64 {
            l.record(Duration::from_micros(i));
        }
        assert_eq!(l.percentile(50.0), Duration::from_micros(50));
        assert_eq!(l.percentile(99.0), Duration::from_micros(99));
        assert_eq!(l.count(), 100);
    }

    #[test]
    fn small_sample_p99_reads_the_tail() {
        // floor-indexing read p99 of {10, 1000} as 10; nearest-rank
        // must read the larger sample
        let mut l = LatencyStats::default();
        l.record(Duration::from_micros(10));
        l.record(Duration::from_micros(1000));
        assert_eq!(l.percentile(99.0), Duration::from_micros(1000));
        assert_eq!(l.percentile(50.0), Duration::from_micros(10));
    }

    #[test]
    fn latency_window_is_bounded_but_count_is_lifetime() {
        let mut l = LatencyStats::with_window(8);
        for i in 1..=100u64 {
            l.record(Duration::from_micros(i));
        }
        assert_eq!(l.count(), 100);
        assert_eq!(l.retained(), 8);
        // window holds the most recent 8 samples: 93..=100
        assert_eq!(l.percentile(1.0), Duration::from_micros(93));
        assert_eq!(l.percentile(100.0), Duration::from_micros(100));
        // mean stays exact over the lifetime, not the window
        assert_eq!(l.mean(), Duration::from_micros(5050 / 100));
    }

    #[test]
    fn latency_merge_adds_lifetimes_and_respects_window() {
        let mut a = LatencyStats::with_window(4);
        let mut b = LatencyStats::with_window(4);
        for i in 1..=6u64 {
            a.record(Duration::from_micros(i));
            b.record(Duration::from_micros(10 * i));
        }
        a.merge(&b);
        assert_eq!(a.count(), 12);
        assert_eq!(a.retained(), 4);
        // the merged-in retained samples displaced a's window
        assert_eq!(a.percentile(100.0), Duration::from_micros(60));
    }

    #[test]
    fn batch_stats_aggregates() {
        let mut b = BatchStats::default();
        b.record(4, Duration::from_micros(100));
        b.record(8, Duration::from_micros(300));
        b.record(1, Duration::from_micros(50));
        assert_eq!(b.batches(), 3);
        assert_eq!(b.queries(), 13);
        assert_eq!(b.max_batch(), 8);
        assert!((b.mean_batch() - 13.0 / 3.0).abs() < 1e-12);
        assert_eq!(b.latency().count(), 3);
        assert_eq!(b.shed(), 0);
        assert_eq!(b.deadline_exceeded(), 0);
        b.record_shed(2);
        b.record_shed(1);
        b.record_deadline_exceeded(4);
        assert_eq!(b.shed(), 3);
        assert_eq!(b.deadline_exceeded(), 4);
        // overload accounting never perturbs the batch/latency series
        assert_eq!(b.batches(), 3);
        assert_eq!(b.queries(), 13);
    }

    #[test]
    fn batch_stats_speculation_counters() {
        let mut b = BatchStats::default();
        assert_eq!((b.speculated(), b.spec_confirmed(),
                    b.spec_discarded()),
                   (0, 0, 0));
        b.record_speculation(10, 4, 6);
        b.record_speculation(5, 5, 0);
        assert_eq!(b.speculated(), 15);
        assert_eq!(b.spec_confirmed(), 9);
        assert_eq!(b.spec_discarded(), 6);
        assert_eq!(b.speculated(),
                   b.spec_confirmed() + b.spec_discarded());
        // speculation accounting never perturbs the batch series
        assert_eq!(b.batches(), 0);
        assert_eq!(b.queries(), 0);
    }

    #[test]
    fn run_metrics_gain() {
        let m = RunMetrics { dist_computations: 100, ..Default::default() };
        assert!((m.gain_vs(8_000) - 80.0).abs() < 1e-9);
    }
}
