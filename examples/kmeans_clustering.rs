//! BMO k-means (Fig 5): Lloyd's with the assignment step solved by
//! per-point bandits over the centroids.
//!
//!     cargo run --release --example kmeans_clustering

use bmonn::coordinator::kmeans::{kmeans_bmo, kmeans_exact, wcss,
                                 KMeansParams};
use bmonn::data::synthetic;
use bmonn::runtime::native::NativeEngine;
use bmonn::util::rng::Rng;

fn main() {
    let (n, d, k) = (1000, 2048, 50);
    // continuous image-like data (the paper's Tiny ImageNet setting) —
    // centroids tile a continuous space, so nearest-centroid gaps are
    // non-degenerate
    let data = synthetic::image_like(n, d, 3);
    println!("k-means: n={n} d={d} k={k}");
    let params = KMeansParams { k, max_iters: 6, ..Default::default() };

    let mut engine = NativeEngine::default();
    let mut rng = Rng::new(4);
    let t0 = std::time::Instant::now();
    let bmo = kmeans_bmo(&data, &params, &mut engine, &mut rng);
    let bmo_time = t0.elapsed();

    let mut rng = Rng::new(4);
    let t1 = std::time::Instant::now();
    let ex = kmeans_exact(&data, &params, &mut rng);
    let exact_time = t1.elapsed();

    let bmo_per = bmo.metrics.dist_computations / bmo.iters as u64;
    let ex_per = ex.metrics.dist_computations / ex.iters as u64;
    println!("\n             units/iter      wcss        time");
    println!("BMO       : {:>12}  {:>10.1}  {bmo_time:>10.2?}",
             bmo_per, wcss(&data, &bmo.centroids, &bmo.assignment));
    println!("exact     : {:>12}  {:>10.1}  {exact_time:>10.2?}",
             ex_per, wcss(&data, &ex.centroids, &ex.assignment));
    println!("\nassignment-step gain : {:.1}x",
             ex_per as f64 / bmo_per as f64);
    println!("assignment accuracy  : {:?}", bmo.assign_accuracy);
    let acc = bmo.assign_accuracy.last().copied().unwrap_or(0.0);
    assert!(acc > 0.95, "assignment accuracy {acc} below 95%");
}
