//! Quickstart: find the 5 nearest neighbors of a point with BMO-NN and
//! compare the cost to exact computation.
//!
//!     cargo run --release --example quickstart

use bmonn::baselines::exact;
use bmonn::coordinator::knn::knn_point_dense;
use bmonn::coordinator::BanditParams;
use bmonn::data::{synthetic, Metric};
use bmonn::metrics::Counter;
use bmonn::runtime::native::NativeEngine;
use bmonn::util::rng::Rng;

fn main() {
    // Tiny-ImageNet-like workload: 2000 image vectors in 4096 dims.
    let (n, d, k) = (2000, 4096, 5);
    let data = synthetic::image_like(n, d, 42);
    println!("dataset: n={n} d={d}, query = point 0, k={k}");

    // --- BMO-NN ----------------------------------------------------------
    let mut engine = NativeEngine::default();
    let mut rng = Rng::new(0);
    let mut counter = Counter::new();
    let params = BanditParams { k, delta: 0.01, ..Default::default() };
    let t0 = std::time::Instant::now();
    let res = knn_point_dense(&data, 0, Metric::L2Sq, &params, &mut engine,
                              &mut rng, &mut counter);
    let bmo_time = t0.elapsed();

    // --- exact baseline ---------------------------------------------------
    let mut c_exact = Counter::new();
    let t1 = std::time::Instant::now();
    let truth = exact::knn_point(&data, 0, k, Metric::L2Sq, &mut c_exact);
    let exact_time = t1.elapsed();

    println!("\nBMO-NN   : {:?}  ({} coord ops, {} exact-evals, {:?})",
             res.ids, counter.get(), res.metrics.exact_evals, bmo_time);
    println!("exact    : {:?}  ({} coord ops, {:?})",
             truth.ids, c_exact.get(), exact_time);
    println!("\ngain     : {:.1}x fewer coordinate-distance computations",
             c_exact.get() as f64 / counter.get() as f64);
    let same = res.ids.iter().collect::<std::collections::HashSet<_>>()
        == truth.ids.iter().collect::<std::collections::HashSet<_>>();
    println!("correct  : {same}");
    assert!(same, "BMO-NN returned a wrong neighbor set");
}
