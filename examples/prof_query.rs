//! Profiling driver for the §Perf pass: 200 full 5-NN queries on the
//! reference workload (n=2048, d=1024). Use with
//! `perf record -g target/release/examples/prof_query`.

use bmonn::coordinator::knn::knn_point_dense;
use bmonn::coordinator::BanditParams;
use bmonn::data::{synthetic, Metric};
use bmonn::metrics::Counter;
use bmonn::runtime::native::NativeEngine;
use bmonn::util::rng::Rng;

fn main() {
    let data = synthetic::image_like(2048, 1024, 7);
    let params = BanditParams { k: 5, ..Default::default() };
    let mut engine = NativeEngine::default();
    let t0 = std::time::Instant::now();
    let mut units = 0u64;
    for rep in 0..200u64 {
        let mut rng = Rng::new(rep);
        let mut c = Counter::new();
        let r = knn_point_dense(&data, (rep % 64) as usize, Metric::L2Sq,
                                &params, &mut engine, &mut rng, &mut c);
        std::hint::black_box(&r);
        units += c.get();
    }
    let el = t0.elapsed();
    println!("200 queries in {el:?} ({} units, {:.2} ns/unit)",
             units, el.as_nanos() as f64 / units as f64);
}
