//! End-to-end serving driver (docs/ARCHITECTURE.md, "The query
//! server"): start the query
//! server on an image-like dataset, fire k-NN queries from concurrent
//! clients, and report latency/throughput/accuracy plus the paper's
//! coordinate-op gain — and the server's dynamic-batching stats, since
//! with 8 concurrent clients the worker pool coalesces queued queries
//! into multi-query bandit passes. This is the "all layers compose"
//! proof: L3 server -> batched coordinator -> pull engines.
//!
//!     cargo run --release --example serve_queries [-- --pjrt]

use std::time::Instant;

use bmonn::baselines::exact;
use bmonn::coordinator::knn::knn_point_dense;
use bmonn::coordinator::server::{Client, Server, ServerConfig};
use bmonn::coordinator::BanditParams;
use bmonn::data::{synthetic, Metric};
use bmonn::metrics::{Counter, LatencyStats};
use bmonn::runtime::pjrt::PjrtEngine;
use bmonn::util::json::Json;
use bmonn::util::rng::Rng;

fn main() {
    let use_pjrt = std::env::args().any(|a| a == "--pjrt");
    let (n, d, k, n_queries, n_clients) = (1500, 1024, 5, 200, 8);
    let data = synthetic::image_like(n, d, 99);
    let queries: Vec<(usize, Vec<f32>)> = {
        let mut rng = Rng::new(5);
        (0..n_queries)
            .map(|_| {
                let q = rng.below(n);
                (q, data.row_vec(q))
            })
            .collect()
    };
    // ground truth for accuracy
    let truth: Vec<Vec<u32>> = queries
        .iter()
        .map(|(q, _)| {
            let mut r = exact::knn_point(&data, *q, k, Metric::L2Sq,
                                         &mut Counter::new());
            r.ids.insert(0, *q as u32); // self is returned by server
            r.ids.truncate(k);
            r.ids.clone()
        })
        .collect();

    // optional: demonstrate the PJRT path composes end-to-end first
    if use_pjrt {
        println!("pjrt preflight: running one query through the AOT \
                  JAX/Pallas artifact ...");
        let mut engine = PjrtEngine::new(
            std::path::Path::new("artifacts"), Metric::L2Sq)
            .expect("artifacts missing - run `make artifacts`");
        let mut params = BanditParams { k, ..Default::default() };
        params.policy.round_pulls = engine.round_pulls();
        let mut rng = Rng::new(17);
        let mut c = Counter::new();
        let res = knn_point_dense(&data, queries[0].0, Metric::L2Sq,
                                  &params, &mut engine, &mut rng, &mut c);
        println!("pjrt preflight OK: {:?} in {} artifact executions\n",
                 res.ids, engine.executions);
    }

    let srv = Server::start(
        data,
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            params: BanditParams { k, ..Default::default() },
            ..Default::default()
        },
    )
    .expect("server start");
    println!("serving on {} ({} queries, {} clients)", srv.addr,
             n_queries, n_clients);

    let addr = srv.addr;
    let t0 = Instant::now();
    let chunks: Vec<Vec<(usize, Vec<f32>)>> = queries
        .chunks(n_queries / n_clients)
        .map(|c| c.to_vec())
        .collect();
    let handles: Vec<_> = chunks
        .into_iter()
        .map(|chunk| {
            std::thread::spawn(move || {
                let mut cl = Client::connect(&addr).unwrap();
                let mut lat = LatencyStats::default();
                let mut answers = Vec::new();
                let mut units = 0u64;
                for (_q, vec) in chunk {
                    let t = Instant::now();
                    let (ids, _d, u) = cl.knn(&vec, k).unwrap();
                    lat.record(t.elapsed());
                    units += u;
                    answers.push(ids);
                }
                (lat, answers, units)
            })
        })
        .collect();
    let mut lat = LatencyStats::default();
    let mut all_answers = Vec::new();
    let mut total_units = 0u64;
    for h in handles {
        let (l, a, u) = h.join().unwrap();
        lat.merge(&l);
        all_answers.extend(a);
        total_units += u;
    }
    let wall = t0.elapsed();

    // accuracy: compare as sets per query (answers arrive in chunk order,
    // which matches the original order because chunks preserve it)
    let mut correct = 0usize;
    for (got, want) in all_answers.iter().zip(&truth) {
        let g: std::collections::HashSet<_> = got.iter().collect();
        let w: std::collections::HashSet<_> = want.iter().collect();
        correct += (g == w) as usize;
    }

    let exact_units = (n_queries * (n - 1) * d) as u64;
    println!("\nthroughput : {:.1} queries/s",
             n_queries as f64 / wall.as_secs_f64());
    println!("latency    : p50 {:?}  p99 {:?}  mean {:?}",
             lat.percentile(50.0), lat.percentile(99.0), lat.mean());
    println!("accuracy   : {:.3} ({} / {} exact top-{k} sets)",
             correct as f64 / n_queries as f64, correct, n_queries);
    println!("coord ops  : {total_units} (exact {exact_units}) -> gain {:.1}x",
             exact_units as f64 / total_units as f64);

    // dynamic-batching telemetry from the worker pool
    let mut cl = Client::connect(&srv.addr).unwrap();
    let stats = cl
        .request(&Json::obj(vec![("op", Json::Str("stats".into()))]))
        .unwrap();
    let f = |key: &str| stats.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0);
    println!("batching   : {} worker passes over {} queries \
              (mean batch {:.2}, max {}, batch p99 {}us)",
             f("batches"), f("queries"), f("mean_batch"), f("max_batch"),
             f("batch_p99_us"));
    assert!(correct as f64 >= 0.97 * n_queries as f64,
            "serving accuracy below 97%");
}
