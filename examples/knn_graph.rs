//! k-NN graph construction (Algorithm 2's outer loop) on an image-like
//! dataset, reporting the paper's headline metric: coordinate-distance
//! computations vs exact graph construction.
//!
//!     cargo run --release --example knn_graph

use bmonn::baselines::exact;
use bmonn::coordinator::knn::knn_graph_dense;
use bmonn::coordinator::BanditParams;
use bmonn::data::{synthetic, Metric};
use bmonn::metrics::Counter;
use bmonn::runtime::native::NativeEngine;
use bmonn::util::rng::Rng;

fn main() {
    let (n, d, k) = (500, 2048, 5);
    let data = synthetic::image_like(n, d, 7);
    println!("building {k}-NN graph over n={n} d={d} ...");

    let mut engine = NativeEngine::default();
    let mut rng = Rng::new(1);
    let mut counter = Counter::new();
    let params = BanditParams { k, delta: 0.01, ..Default::default() };
    let t0 = std::time::Instant::now();
    let g = knn_graph_dense(&data, Metric::L2Sq, &params, &mut engine,
                            &mut rng, &mut counter);
    let elapsed = t0.elapsed();

    // accuracy vs brute force on a sample of nodes
    let mut correct = 0;
    let sample = 50.min(n);
    for q in 0..sample {
        let truth = exact::knn_point(&data, q, k, Metric::L2Sq,
                                     &mut Counter::new());
        let got: std::collections::HashSet<_> =
            g.neighbors[q].iter().collect();
        let want: std::collections::HashSet<_> = truth.ids.iter().collect();
        correct += (got == want) as usize;
    }
    let exact_units = (n * (n - 1) * d) as u64;
    println!("done in {elapsed:?}");
    println!("coordinate ops : {} (exact would be {})", counter.get(),
             exact_units);
    println!("gain           : {:.1}x",
             exact_units as f64 / counter.get() as f64);
    println!("accuracy       : {}/{sample} sampled nodes exact", correct);
    println!("exact-evals    : {}", g.metrics.exact_evals);
    assert!(correct * 100 >= sample * 95, "graph accuracy below 95%");
}
