# pytest: Pallas kernels vs pure-jnp ref — the CORE correctness signal.
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels import bmo_pull, ref, wht

jax.config.update("jax_enable_x64", False)


def _rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale)


@pytest.mark.parametrize("metric", ["l2", "l1"])
@pytest.mark.parametrize("b,d,t", [(64, 1024, 256), (8, 32, 16), (1, 4, 1)])
def test_pull_rows_matches_ref(metric, b, d, t):
    rows = _rand((b, d), 0)
    query = _rand((d,), 1)
    rng = np.random.default_rng(2)
    cids = jnp.asarray(rng.integers(0, d, size=t).astype(np.int32))
    got_s, got_q = bmo_pull.pull_rows(rows, query, cids, metric=metric)
    want_s, want_q = ref.pull_rows_moments_ref(rows, query, cids,
                                               metric=metric)
    np.testing.assert_allclose(got_s, want_s, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got_q, want_q, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("metric", ["l2", "l1"])
def test_pull_data_matches_ref(metric):
    n, d, b, t = 128, 64, 16, 32
    data = _rand((n, d), 3)
    query = _rand((d,), 4)
    rng = np.random.default_rng(5)
    arms = jnp.asarray(rng.integers(0, n, size=b).astype(np.int32))
    cids = jnp.asarray(rng.integers(0, d, size=t).astype(np.int32))
    got_s, _got_q = bmo_pull.pull_data(data, query, arms, cids,
                                       metric=metric)
    want = ref.pull_data_ref(data, query, arms, cids, metric=metric)
    np.testing.assert_allclose(got_s, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("metric", ["l2", "l1"])
@pytest.mark.parametrize("b,d", [(64, 1024), (16, 64), (3, 7)])
def test_exact_rows_matches_ref(metric, b, d):
    rows = _rand((b, d), 6)
    query = _rand((d,), 7)
    got = bmo_pull.exact_rows(rows, query, metric=metric)
    want = ref.exact_rows_ref(rows, query, metric=metric)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_pull_is_unbiased_estimator_of_exact():
    """E[pull/T * d] == exact distance: the paper's Eq. (2)/(4) invariant."""
    b, d, t, reps = 4, 256, 64, 200
    rows = _rand((b, d), 8)
    query = _rand((d,), 9)
    exact = np.asarray(ref.exact_rows_ref(rows, query, metric="l2"))
    rng = np.random.default_rng(10)
    acc = np.zeros(b)
    for _ in range(reps):
        cids = jnp.asarray(rng.integers(0, d, size=t).astype(np.int32))
        acc += np.asarray(bmo_pull.pull_rows(rows, query, cids)[0]) / t * d
    est = acc / reps
    np.testing.assert_allclose(est, exact, rtol=0.15)


# ----------------------------------------------------------------- WHT ---

@pytest.mark.parametrize("b,d", [(16, 64), (4, 8), (2, 2), (1, 1)])
def test_fwht_matches_matrix_ref(b, d):
    x = _rand((b, d), 11)
    got = wht.fwht(x)
    want = ref.fwht_ref(x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_rotate_preserves_pairwise_l2():
    """Lemma 3 prerequisite: H D is orthonormal, pairwise l2 preserved."""
    b, d = 8, 128
    x = _rand((b, d), 12)
    rng = np.random.default_rng(13)
    signs = jnp.asarray(rng.choice([-1.0, 1.0], size=d).astype(np.float32))
    xr = np.asarray(wht.rotate(x, signs))
    x = np.asarray(x)
    for i in range(b):
        for j in range(i + 1, b):
            a = np.sum((x[i] - x[j]) ** 2)
            bb = np.sum((xr[i] - xr[j]) ** 2)
            np.testing.assert_allclose(a, bb, rtol=1e-4)


def test_rotate_flattens_spiky_vectors():
    """The point of Lemma 3: a 1-hot difference spreads across coords."""
    d = 256
    x = np.zeros((2, d), dtype=np.float32)
    x[0, 17] = 10.0  # pair differs in exactly one coordinate
    rng = np.random.default_rng(14)
    signs = jnp.asarray(rng.choice([-1.0, 1.0], size=d).astype(np.float32))
    xr = np.asarray(wht.rotate(jnp.asarray(x), signs))
    coord_sq = (xr[0] - xr[1]) ** 2
    # before: max coord-dist = 100; after: all coords equal at 100/d
    assert coord_sq.max() < 100.0 / d * 1.01
    np.testing.assert_allclose(coord_sq, np.full(d, 100.0 / d), rtol=1e-3)


# ------------------------------------------------------ hypothesis sweep ---

@settings(max_examples=30, deadline=None)
@given(
    b=st.integers(1, 16),
    d=st.integers(1, 64),
    t=st.integers(1, 48),
    metric=st.sampled_from(["l2", "l1"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pull_rows_hypothesis(b, d, t, metric, seed):
    rng = np.random.default_rng(seed)
    rows = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    query = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    cids = jnp.asarray(rng.integers(0, d, size=t).astype(np.int32))
    got_s, got_q = bmo_pull.pull_rows(rows, query, cids, metric=metric)
    want_s, want_q = ref.pull_rows_moments_ref(rows, query, cids,
                                               metric=metric)
    np.testing.assert_allclose(got_s, want_s, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got_q, want_q, rtol=1e-3, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 8),
    logd=st.integers(0, 7),
    seed=st.integers(0, 2**31 - 1),
)
def test_rotate_hypothesis_norm_preserved(b, logd, seed):
    d = 1 << logd
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    signs = jnp.asarray(rng.choice([-1.0, 1.0], size=d).astype(np.float32))
    xr = np.asarray(wht.rotate(x, signs))
    np.testing.assert_allclose(
        np.sum(np.asarray(x) ** 2, axis=1),
        np.sum(xr**2, axis=1),
        rtol=1e-3,
    )


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 12),
    d=st.integers(1, 48),
    metric=st.sampled_from(["l2", "l1"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_exact_rows_hypothesis(b, d, metric, seed):
    rng = np.random.default_rng(seed)
    rows = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    query = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    got = bmo_pull.exact_rows(rows, query, metric=metric)
    want = ref.exact_rows_ref(rows, query, metric=metric)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
