# pytest: L2 graph shapes/semantics + AOT lowering smoke.
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref


def test_artifact_specs_cover_expected_set():
    names = set(model.artifact_specs().keys())
    assert {
        "pull_rows_l2", "pull_rows_l1",
        "pull_data_l2", "pull_data_l1",
        "exact_rows_l2", "exact_rows_l1",
        "rotate", "topk_scan",
    } == names


@pytest.mark.parametrize("name", sorted(model.artifact_specs().keys()))
def test_artifact_runs_at_declared_shapes(name):
    fn, in_specs, _meta = model.artifact_specs()[name]
    rng = np.random.default_rng(42)
    args = []
    for s in in_specs:
        if s.dtype == jnp.int32:
            hi = s.shape[0] if len(s.shape) == 1 else 2
            # index inputs must stay in range of the gathered axis;
            # use the smallest plausible bound (d or n from meta)
            bound = min(_meta.get("d", 8), _meta.get("n", _meta.get("d", 8)))
            args.append(jnp.asarray(
                rng.integers(0, bound, size=s.shape).astype(np.int32)))
        else:
            args.append(jnp.asarray(
                rng.normal(size=s.shape).astype(np.float32)))
    out = jax.jit(fn)(*args)
    assert isinstance(out, tuple) and len(out) >= 1


def test_topk_scan_matches_numpy():
    fn, _, meta = model.artifact_specs()["topk_scan"]
    n, d, k = meta["n"], meta["d"], meta["k"]
    rng = np.random.default_rng(0)
    data = rng.normal(size=(n, d)).astype(np.float32)
    query = rng.normal(size=(d,)).astype(np.float32)
    vals, ids = fn(jnp.asarray(data), jnp.asarray(query))
    dists = np.sum((data - query) ** 2, axis=1)
    want_ids = np.argsort(dists)[:k]
    np.testing.assert_allclose(np.sort(vals), np.sort(dists[want_ids]),
                               rtol=1e-4)
    assert set(np.asarray(ids).tolist()) == set(want_ids.tolist())


def test_rotate_graph_matches_matrix_ref():
    fn, in_specs, meta = model.artifact_specs()["rotate"]
    b, d = meta["b"], meta["d"]
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    signs = jnp.asarray(rng.choice([-1.0, 1.0], size=d).astype(np.float32))
    (got,) = fn(x, signs)
    want = ref.rotate_ref(x, signs)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("name", ["pull_rows_l2", "exact_rows_l1", "rotate"])
def test_aot_lowering_produces_hlo_text(name):
    fn, in_specs, _ = model.artifact_specs()[name]
    text = aot.lower_one(name, fn, in_specs)
    assert "HloModule" in text
    assert "ENTRY" in text
