"""AOT pipeline: lower every L2 graph to HLO *text* artifacts.

Interchange format is HLO text, NOT ``lowered.compile().serialize()`` —
the image's xla_extension 0.5.1 rejects jax>=0.5 protos with 64-bit
instruction ids; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md). Lowered with return_tuple=True; the rust
side unwraps with ``Literal::to_tuple*``.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
Emits one ``<name>.hlo.txt`` per artifact plus ``manifest.json`` recording
the input/output shapes the rust runtime validates against.
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(name, fn, in_specs):
    lowered = jax.jit(fn).lower(*in_specs)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact names to rebuild")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = {"artifacts": {}}
    for name, (fn, in_specs, meta) in model.artifact_specs().items():
        if only and name not in only:
            continue
        text = lower_one(name, fn, in_specs)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)}
                for s in in_specs
            ],
            "meta": meta,
        }
        print(f"  {name}: {len(text)} chars -> {path}")

    man_path = os.path.join(args.out_dir, "manifest.json")
    # Merge with an existing manifest when rebuilding a subset.
    if only and os.path.exists(man_path):
        with open(man_path) as f:
            old = json.load(f)
        old["artifacts"].update(manifest["artifacts"])
        manifest = old
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {man_path}")


if __name__ == "__main__":
    main()
