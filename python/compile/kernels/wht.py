"""L1 Pallas kernel: fast Walsh-Hadamard transform (Lemma 3 rotation).

The Ailon-Chazelle randomized rotation ``H D x`` preprocesses a dataset so
the coordinate-wise squared distances concentrate (lighter tails -> smaller
sub-Gaussian constant -> fewer pulls). ``D`` is a random +-1 diagonal and
``H`` the orthonormal Hadamard matrix, applied in O(d log d) by the
in-register butterfly below.

Grid: 1-D over row tiles; each tile holds ``BLOCK_ROWS`` full rows in VMEM
(the butterfly is a pure permutation+add network along the row axis, so a
row never leaves its tile -- no cross-tile traffic). The log2(d) stages are
statically unrolled at trace time.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 16


def _fwht_body(x):
    b, d = x.shape
    h = 1
    while h < d:
        x = x.reshape(b, d // (2 * h), 2, h)
        lo = x[:, :, 0, :]
        hi = x[:, :, 1, :]
        x = jnp.stack([lo + hi, lo - hi], axis=2).reshape(b, d)
        h *= 2
    return x / jnp.sqrt(jnp.asarray(d, x.dtype))


def _rotate_kernel(x_ref, s_ref, o_ref):
    """One row tile: sign flip then statically-unrolled butterfly."""
    o_ref[...] = _fwht_body(x_ref[...] * s_ref[...])


def rotate(x, signs, *, block_rows=BLOCK_ROWS):
    """Orthonormal randomized rotation ``(H D) x`` per row.

    x f32[B, D] (D a power of two), signs f32[D] in {-1, +1} -> f32[B, D].
    Distance-preserving for l2: used to build the rotated Monte Carlo box.
    """
    b, d = x.shape
    assert d & (d - 1) == 0, "FWHT requires power-of-two dimension"
    if b % block_rows != 0:
        block_rows = b
    s2 = signs[None, :]
    return pl.pallas_call(
        _rotate_kernel,
        grid=(b // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), x.dtype),
        interpret=True,
    )(x, s2)


def fwht(x, *, block_rows=BLOCK_ROWS):
    """Plain orthonormal FWHT (no sign diagonal)."""
    return rotate(x, jnp.ones((x.shape[1],), x.dtype), block_rows=block_rows)
