"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Every Pallas kernel in this package has an exact reference implementation
here; python/tests asserts allclose between the two over a hypothesis sweep
of shapes and dtypes. These are also the semantic definition of what the
rust native backend must compute (rust/src/runtime/native.rs mirrors them).
"""

import jax.numpy as jnp


def pull_rows_ref(rows, query, coord_ids, metric="l2"):
    """Partial distances of `rows` to `query` over sampled coordinates.

    rows:      f32[B, D]  candidate-arm rows
    query:     f32[D]
    coord_ids: i32[T]     sampled coordinate indices (with replacement)
    returns    f32[B]     sum over t of rho(rows[b, c_t], query[c_t])
    """
    g = rows[:, coord_ids]          # [B, T]
    q = query[coord_ids]            # [T]
    diff = g - q[None, :]
    if metric == "l2":
        v = diff * diff
    elif metric == "l1":
        v = jnp.abs(diff)
    else:
        raise ValueError(f"unknown metric {metric}")
    return jnp.sum(v, axis=1)


def pull_rows_moments_ref(rows, query, coord_ids, metric="l2"):
    """(Σx, Σx²) per arm — matches the two-output Pallas pull kernel."""
    g = rows[:, coord_ids]
    q = query[coord_ids]
    diff = g - q[None, :]
    if metric == "l2":
        v = diff * diff
    elif metric == "l1":
        v = jnp.abs(diff)
    else:
        raise ValueError(f"unknown metric {metric}")
    return jnp.sum(v, axis=1), jnp.sum(v * v, axis=1)


def pull_data_ref(data, query, arm_ids, coord_ids, metric="l2"):
    """Device-resident variant: gather arm rows from the full dataset.

    data:      f32[N, D]
    arm_ids:   i32[B]
    """
    return pull_rows_ref(data[arm_ids], query, coord_ids, metric)


def exact_rows_ref(rows, query, metric="l2"):
    """Full (un-normalized) distances of each row to query. f32[B]."""
    diff = rows - query[None, :]
    if metric == "l2":
        v = diff * diff
    elif metric == "l1":
        v = jnp.abs(diff)
    else:
        raise ValueError(f"unknown metric {metric}")
    return jnp.sum(v, axis=1)


def fwht_ref(x):
    """Orthonormal Walsh-Hadamard transform via the explicit matrix.

    x: f32[B, D] with D a power of two. O(D^2) — test oracle only.
    """
    d = x.shape[-1]
    h = jnp.array([[1.0]])
    while h.shape[0] < d:
        h = jnp.block([[h, h], [h, -h]])
    return (x @ h.T) / jnp.sqrt(d)


def rotate_ref(x, signs):
    """Randomized orthonormal rotation H @ D (Ailon-Chazelle)."""
    return fwht_ref(x * signs[None, :])
