"""L1 Pallas kernels: the BMO-NN compute hot-spot.

The hot path of BMO-NN is a *batched arm pull*: for a block of B candidate
arms and T sampled coordinates, reduce the coordinate-wise distances
``rho(rows[b, c_t], query[c_t])`` to a per-arm partial sum. This is a
gather + elementwise + row-reduce, i.e. bandwidth-bound; the TPU-shaped
design therefore:

  * pre-gathers the sampled coordinates into a dense ``[B, T]`` tile in the
    surrounding L2 jax graph (XLA gather is the HBM-side schedule), so the
    kernel body is a dense vectorized VPU reduction;
  * tiles arms with a 1-D grid and ``BlockSpec`` so each
    ``BLOCK_ARMS x T`` tile (default 64x256 f32 = 64 KiB) fits VMEM
    alongside the resident query tile;
  * keeps the exact-distance fallback as a 2-D grid (arm-tile x dim-tile)
    with an accumulating output block, the classic double-buffered
    HBM->VMEM streaming reduction.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; numerics are validated through the interpret path against
``ref.py`` and the real-TPU perf is estimated from the VMEM footprint
(see docs/ARCHITECTURE.md, "The PJRT runtime").
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes; chosen so one (gathered, query, out) working set is
# ~64-130 KiB, far under the ~16 MiB VMEM of a modern TPU core and small
# enough for interpret-mode CPU testing to stay fast.
BLOCK_ARMS = 64
BLOCK_DIM = 256


def _pull_kernel(g_ref, q_ref, o_ref, o2_ref, *, metric):
    """One arm-tile: reduce coordinate distances across the T axis.

    g_ref:  f32[BLOCK_ARMS, T] gathered candidate values
    q_ref:  f32[1, T]          gathered query values (resident)
    o_ref:  f32[BLOCK_ARMS]    per-arm partial sums Σx
    o2_ref: f32[BLOCK_ARMS]    per-arm second moments Σx² (feeds the
                               coordinator's empirical-variance CIs)
    """
    diff = g_ref[...] - q_ref[...]  # broadcast over arms
    if metric == "l2":
        v = diff * diff
    else:  # l1
        v = jnp.abs(diff)
    o_ref[...] = jnp.sum(v, axis=1)
    o2_ref[...] = jnp.sum(v * v, axis=1)


def pull_gathered(gathered, query_g, *, metric="l2", block_arms=BLOCK_ARMS):
    """Pallas reduction over pre-gathered tiles -> (Σx, Σx²) per arm.

    gathered: f32[B, T]; query_g: f32[T]. B must be a multiple of
    block_arms (the AOT shapes guarantee this; tests sweep it).
    """
    b, t = gathered.shape
    if b % block_arms != 0:
        block_arms = b  # degenerate single-tile fallback for odd test shapes
    q2 = query_g[None, :]
    return pl.pallas_call(
        functools.partial(_pull_kernel, metric=metric),
        grid=(b // block_arms,),
        in_specs=[
            pl.BlockSpec((block_arms, t), lambda i: (i, 0)),
            pl.BlockSpec((1, t), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_arms,), lambda i: (i,)),
            pl.BlockSpec((block_arms,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), gathered.dtype),
            jax.ShapeDtypeStruct((b,), gathered.dtype),
        ],
        interpret=True,
    )(gathered, q2)


def pull_rows(rows, query, coord_ids, *, metric="l2", block_arms=BLOCK_ARMS):
    """Batched pull over explicit candidate rows.

    rows f32[B, D], query f32[D], coord_ids i32[T] -> (f32[B], f32[B]):
    per-arm (Σx, Σx²). The gather lives in the L2 graph (lowers to XLA
    gather); the reduction is the L1 Pallas kernel.
    """
    g = jnp.take(rows, coord_ids, axis=1)
    qg = jnp.take(query, coord_ids, axis=0)
    return pull_gathered(g, qg, metric=metric, block_arms=block_arms)


def pull_data(data, query, arm_ids, coord_ids, *, metric="l2",
              block_arms=BLOCK_ARMS):
    """Device-resident variant: data f32[N, D] stays on device; per round
    only arm_ids i32[B] and coord_ids i32[T] cross the host boundary."""
    rows = jnp.take(data, arm_ids, axis=0)
    return pull_rows(rows, query, coord_ids, metric=metric,
                     block_arms=block_arms)


def _exact_kernel(r_ref, q_ref, o_ref, *, metric):
    """Accumulating exact-distance tile: grid (arm-tile i, dim-tile j).

    The output block is revisited for every j; j==0 zero-initializes.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    diff = r_ref[...] - q_ref[...]
    if metric == "l2":
        v = diff * diff
    else:
        v = jnp.abs(diff)
    o_ref[...] += jnp.sum(v, axis=1)


def exact_rows(rows, query, *, metric="l2", block_arms=BLOCK_ARMS,
               block_dim=BLOCK_DIM):
    """Full distances rows f32[B, D] vs query f32[D] -> f32[B]."""
    b, d = rows.shape
    if b % block_arms != 0:
        block_arms = b
    if d % block_dim != 0:
        block_dim = d
    q2 = query[None, :]
    return pl.pallas_call(
        functools.partial(_exact_kernel, metric=metric),
        grid=(b // block_arms, d // block_dim),
        in_specs=[
            pl.BlockSpec((block_arms, block_dim), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_dim), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_arms,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), rows.dtype),
        interpret=True,
    )(rows, q2)
