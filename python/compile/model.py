"""L2: the jax compute graphs BMO-NN's coordinator executes via PJRT.

Each public function here composes the L1 Pallas kernels (kernels/) into a
fixed-shape graph that ``aot.py`` lowers once to HLO text. The rust runtime
(rust/src/runtime/) loads those artifacts and calls them from the hot path;
python never runs at query time.

Graphs (all return 1-tuples — the AOT bridge lowers with return_tuple=True
and the rust side unwraps with ``to_tuple1``):

  pull_rows_{l2,l1}   rows[B,D], query[D], coord_ids[T]      -> (Σx[B], Σx²[B])
  pull_data_{l2,l1}   data[N,D], query[D], arms[B], coords[T]-> (Σx[B], Σx²[B])
  exact_rows_{l2,l1}  rows[B,D], query[D]                    -> dists[B]
  rotate              x[B,D], signs[D]                       -> x'[B,D]
  topk_scan           dists[N] (full exact pass)             -> (vals[K], ids[K])
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import bmo_pull, wht


def make_pull_rows(metric):
    def pull_rows(rows, query, coord_ids):
        # the kernel returns (Σx, Σx²) — already the output tuple
        return bmo_pull.pull_rows(rows, query, coord_ids, metric=metric)

    pull_rows.__name__ = f"pull_rows_{metric}"
    return pull_rows


def make_pull_data(metric):
    def pull_data(data, query, arm_ids, coord_ids):
        return bmo_pull.pull_data(data, query, arm_ids, coord_ids,
                                  metric=metric)

    pull_data.__name__ = f"pull_data_{metric}"
    return pull_data


def make_exact_rows(metric):
    def exact_rows(rows, query):
        return (bmo_pull.exact_rows(rows, query, metric=metric),)

    exact_rows.__name__ = f"exact_rows_{metric}"
    return exact_rows


def rotate(x, signs):
    return (wht.rotate(x, signs),)


def make_topk_scan(k):
    """Exact-computation baseline graph: brute-force distances + top-k.

    Used by the coordinator for the exact fallback over a whole (padded)
    dataset slab: data[N,D] vs query -> k smallest l2^2 dists + indices.
    """

    def topk_scan(data, query):
        diff = data - query[None, :]
        dists = jnp.sum(diff * diff, axis=1)
        neg_vals, ids = jax.lax.top_k(-dists, k)
        return (-neg_vals, ids.astype(jnp.int32))

    topk_scan.__name__ = f"topk_scan_k{k}"
    return topk_scan


# ---------------------------------------------------------------------------
# Artifact registry: name -> (fn, example shapes). These are the shapes the
# default `make artifacts` bundle compiles; the rust runtime pads datasets
# and batches up to them (see runtime/artifacts.rs).
# ---------------------------------------------------------------------------

F32 = jnp.float32
I32 = jnp.int32


def _s(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


# Default bundle dimensions. B/T match the batched pull policy defaults
# (coordinator/batch.rs): 64 arms per round, 256 pulls per arm per round.
N, D, B, T, K = 2048, 1024, 64, 256, 10


def artifact_specs():
    """name -> (callable, [ShapeDtypeStruct inputs], metadata dict)."""
    specs = {}
    for metric in ("l2", "l1"):
        specs[f"pull_rows_{metric}"] = (
            make_pull_rows(metric),
            [_s((B, D)), _s((D,)), _s((T,), I32)],
            {"b": B, "d": D, "t": T, "metric": metric},
        )
        specs[f"pull_data_{metric}"] = (
            make_pull_data(metric),
            [_s((N, D)), _s((D,)), _s((B,), I32), _s((T,), I32)],
            {"n": N, "b": B, "d": D, "t": T, "metric": metric},
        )
        specs[f"exact_rows_{metric}"] = (
            make_exact_rows(metric),
            [_s((B, D)), _s((D,))],
            {"b": B, "d": D, "metric": metric},
        )
    specs["rotate"] = (
        rotate,
        [_s((B, D)), _s((D,))],
        {"b": B, "d": D},
    )
    specs["topk_scan"] = (
        make_topk_scan(K),
        [_s((N, D)), _s((D,))],
        {"n": N, "d": D, "k": K},
    )
    return specs
